//! Engine threads: each replica owns a (non-`Send`) PJRT runtime and
//! serves execution requests over channels — the executor-thread pattern
//! a production GPU server uses.  The coordinator and its worker pool
//! stay fully `Send`.
//!
//! PR 3 replicates the engine: `EnginePool` spawns N replica threads
//! (each with its own `Runtime`, preloaded checkpoints, and precompiled
//! executables) behind a load-aware dispatcher (`DispatchState`,
//! DESIGN.md §5.7).  A batch routes to the replica with the fewest
//! in-flight batches; a (task, policy) group is pinned to one replica
//! while it has batches in flight — same-replica FIFO execution keeps the
//! group's batches in submit order — and may migrate once it drains.
//!
//! Each replica's request loop is a software pipeline (DESIGN.md §5.4):
//! while batch N executes on the device, batch N+1's host arrays are
//! uploaded, and batch N's readback is deferred until N+1 has been
//! launched, so the device never idles waiting on a host copy.  Readback
//! results (de-batching, reply dispatch) are handed to the shared
//! `exec::ThreadPool` instead of blocking the engine thread.  Jobs carry
//! only interned `TaskId`/`PolicyId` — no strings on the hot path; the
//! engine selects the executable through its mirrored `policy -> exec
//! mode` table (manifest-derived, so it agrees with the coordinator's
//! without a handshake — DESIGN.md §6.3).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::exec::ThreadPool;
use crate::model::manifest::{Manifest, ModeId, PolicyId, TaskId};
use crate::model::tensor::Tensor;
use crate::model::Container;

use super::staging::{StagingBuf, StagingPool};
use super::{PendingOutputs, Runtime};

/// Completion callback: runs on the shared worker pool with the batch
/// result (readback stage output).  Owning the per-request reply senders,
/// it is where de-batching and reply dispatch happen.
pub type Completion = Box<dyn FnOnce(Result<InferDone>) + Send + 'static>;

/// Cancel-before-submit hook (DESIGN.md §5.8): the engine thread calls
/// this once per job, after de-queueing it and *before* any device work
/// (upload/launch).  `true` abandons the batch — its completion runs
/// with a [`CancelledBeforeSubmit`] error and the staging buffer is
/// recycled untouched.  This is the only cancellation point past batch
/// formation; once upload starts a batch always executes to completion.
pub type CancelCheck = Box<dyn Fn() -> bool + Send + 'static>;

/// Sentinel error a cancelled job's completion receives; completions
/// `downcast_ref` it to tell deadline expiry from real engine failures.
#[derive(Debug, Clone, Copy)]
pub struct CancelledBeforeSubmit;

impl std::fmt::Display for CancelledBeforeSubmit {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("batch cancelled before engine submit (every request past its deadline)")
    }
}

impl std::error::Error for CancelledBeforeSubmit {}

pub struct InferJob {
    pub task: TaskId,
    /// Interned precision policy; the engine maps it to its executable
    /// mode via the mirrored `policy_exec` table.
    pub policy: PolicyId,
    /// Pooled host buffers: `bucket * seq` ids/type_ids/mask.  Recycled to
    /// the staging pool by the engine right after the device upload.
    pub staging: StagingBuf,
    /// Checked once before upload; `None` = never cancel (the common
    /// case: only all-deadline batches carry a check).
    pub cancel: Option<CancelCheck>,
    pub done: Completion,
}

pub struct InferDone {
    pub logits: Tensor,
    /// launch -> readback-complete time (engine-thread measured), us.
    /// The clock starts *after* `upload_inputs` returns, so `upload_us`
    /// is never double-counted here.  Under overlap this still includes
    /// the next batch's upload window.
    pub exec_us: u64,
    /// host -> device input copy time, microseconds.
    pub upload_us: u64,
    /// whole-job engine time (job receipt -> readback complete), us —
    /// the same quantity `Timing::engine_us` carries to clients (the
    /// end-to-end time is `Timing::total_us`, a different clock).
    /// Invariant: `upload_us + exec_us <= engine_us`.
    pub engine_us: u64,
    /// Replica that executed the batch (0 for a single engine).
    pub replica: usize,
    /// Per-replica batch serial, stamped in execution order — combined
    /// with `replica`, the cross-replica FIFO witness (same-replica
    /// batches of a group execute in submit order).
    pub exec_seq: u64,
}

enum Msg {
    Infer(Box<InferJob>),
    Stop,
}

/// Route/policy tables mirrored out of the engine-side manifest at
/// startup: both sides derive ids from the same `manifest.json`, so the
/// coordinator's and engine's tables are identical by construction (the
/// parity the policy integration tests pin).
struct RouteTables {
    tasks: Vec<String>,
    modes: Vec<String>,
    policies: Vec<String>,
    /// `[policy] -> executable mode` — the engine-side half of policy
    /// executable selection.
    policy_exec: Vec<ModeId>,
}

/// `Send` handle to one engine replica thread.
pub struct Engine {
    tx: Sender<Msg>,
    join: Option<JoinHandle<()>>,
    /// Route tables mirrored from the engine-side manifest so blocking
    /// (CLI/test) callers can resolve names without loading it again.
    tasks: Vec<String>,
    modes: Vec<String>,
    policies: Vec<String>,
    policy_exec: Vec<ModeId>,
}

/// A spawned-but-not-ready replica: the thread is live (uploading
/// checkpoints, precompiling executables) but has not reported its route
/// tables yet.  `EnginePool::spawn` starts all replicas in this state so
/// startup preload/precompile fans out concurrently, then waits on each.
struct PendingEngine {
    tx: Sender<Msg>,
    join: JoinHandle<()>,
    ready_rx: Receiver<Result<RouteTables>>,
}

impl PendingEngine {
    fn wait(self) -> Result<Engine> {
        let tables = self
            .ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Engine {
            tx: self.tx,
            join: Some(self.join),
            tasks: tables.tasks,
            modes: tables.modes,
            policies: tables.policies,
            policy_exec: tables.policy_exec,
        })
    }
}

/// Engine tuning knobs.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Overlap upload/execute/readback (one batch in flight behind the
    /// head).  `false` restores the strictly serial per-batch loop — kept
    /// for A/B benchmarking the pipeline win.
    pub overlap: bool,
    /// Engine replicas behind the pool dispatcher (min 1).  Each replica
    /// owns its own PJRT runtime, checkpoints, and executables.
    pub replicas: usize,
    /// Test-only service-rate throttle: sleep this long per de-queued
    /// job, before the cancel check and any device work.  The overload
    /// integration suite uses it to build deterministic queue pressure
    /// (`ServerConfig::throttle_batch`); never set in production.
    pub throttle: Option<std::time::Duration>,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions { overlap: true, replicas: 1, throttle: None }
    }
}

impl Engine {
    /// Spawn one engine replica and wait for it to become ready: it loads
    /// the manifest, uploads every (task, mode) checkpoint in `preload`,
    /// and pre-compiles the executables for the requested (mode, seq
    /// bucket, batch bucket) grid cells so the serving hot path never
    /// compiles.  `pool` runs completion callbacks; `staging` receives
    /// recycled host buffers.
    pub fn spawn(
        artifacts: PathBuf,
        preload: Vec<(String, String, Container)>,
        precompile: Vec<(String, usize, usize)>,
        pool: Arc<ThreadPool>,
        staging: Arc<StagingPool>,
        options: EngineOptions,
    ) -> Result<Engine> {
        Self::spawn_replica(artifacts, Arc::new(preload), precompile, pool, staging, options, 0)?
            .wait()
    }

    /// Start a replica thread without waiting for readiness (the pool
    /// spawns all replicas first, then waits, so checkpoint upload and
    /// executable compilation run concurrently across replicas).
    fn spawn_replica(
        artifacts: PathBuf,
        preload: Arc<Vec<(String, String, Container)>>,
        precompile: Vec<(String, usize, usize)>,
        pool: Arc<ThreadPool>,
        staging: Arc<StagingPool>,
        options: EngineOptions,
        replica: usize,
    ) -> Result<PendingEngine> {
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<RouteTables>>();
        let join = std::thread::Builder::new()
            .name(format!("zqhero-engine-{replica}"))
            .spawn(move || {
                engine_main(
                    artifacts, preload, precompile, rx, ready_tx, pool, staging, options, replica,
                )
            })
            .context("spawning engine thread")?;
        Ok(PendingEngine { tx, join, ready_rx })
    }

    /// Enqueue a job; on failure (engine gone) the job is handed back so
    /// the caller can recycle its staging buffer and fail its requests.
    pub fn submit(&self, job: InferJob) -> std::result::Result<(), Box<InferJob>> {
        self.tx.send(Msg::Infer(Box::new(job))).map_err(|e| match e.0 {
            Msg::Infer(job) => job,
            Msg::Stop => unreachable!("submit only sends Infer"),
        })
    }

    pub fn task_id(&self, name: &str) -> Result<TaskId> {
        crate::model::manifest::intern_position(&self.tasks, name)
            .map(TaskId)
            .with_context(|| format!("unknown task {name:?}"))
    }

    pub fn mode_id(&self, name: &str) -> Result<ModeId> {
        crate::model::manifest::intern_position(&self.modes, name)
            .map(ModeId)
            .with_context(|| format!("unknown mode {name:?}"))
    }

    /// Resolve a policy name against the engine's mirrored table (uniform
    /// mode names included).
    pub fn policy_id(&self, name: &str) -> Result<PolicyId> {
        crate::model::manifest::intern_position(&self.policies, name)
            .map(PolicyId)
            .with_context(|| format!("unknown policy {name:?} (have {:?})", self.policies))
    }

    /// The mirrored policy-name table (parity checks against the
    /// coordinator's `Manifest::policy_order`).
    pub fn policy_names(&self) -> &[String] {
        &self.policies
    }

    /// The executable mode this policy selects on the engine.
    pub fn policy_exec_mode(&self, policy: PolicyId) -> Result<ModeId> {
        self.policy_exec
            .get(policy.index())
            .copied()
            .with_context(|| format!("PolicyId {} out of range", policy.0))
    }

    /// Synchronous convenience call (CLI paths, tests).  `route` is a
    /// policy name (uniform mode names work).  `ids`/`type_ids` are
    /// `[bucket * seq_bucket]` — the seq bucket derives from the payload
    /// length and must exist in the manifest grid; the mask is derived
    /// from PAD positions.
    pub fn infer_blocking(
        &self,
        task: &str,
        route: &str,
        bucket: usize,
        ids: Vec<i32>,
        type_ids: Vec<i32>,
    ) -> Result<InferDone> {
        if bucket == 0 || ids.len() % bucket != 0 {
            // deriving seq from a ragged payload would silently truncate
            // trailing tokens at from_parts' resize
            anyhow::bail!("ids len {} not a multiple of bucket {bucket}", ids.len());
        }
        let seq = ids.len() / bucket;
        let staging = StagingBuf::from_parts(bucket, seq, ids, type_ids);
        let (reply, rx) = channel();
        self.submit(InferJob {
            task: self.task_id(task)?,
            policy: self.policy_id(route)?,
            staging,
            cancel: None,
            done: Box::new(move |res| {
                let _ = reply.send(res);
            }),
        })
        .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Load-aware replica dispatch state, shared by `EnginePool::submit`
/// (batcher thread) and batch completions (worker pool): per-replica
/// in-flight batch counts plus per-group pins.  A (task, policy) group is
/// pinned to one replica while it has batches in flight — same-replica
/// FIFO execution keeps its batches in submit order — and may migrate to
/// the least-loaded replica once it drains (DESIGN.md §5.7).  Pure state
/// machine: unit- and property-tested without engine threads.
pub struct DispatchState {
    /// Batches submitted to each replica and not yet completed.
    inflight: Vec<AtomicUsize>,
    /// Replicas whose engine thread is gone (submit failed): excluded
    /// from least-loaded choice so a dead replica — which would
    /// otherwise sit at zero in-flight and win every tie — cannot
    /// attract all traffic and turn one failure into a full outage.
    dead: Vec<std::sync::atomic::AtomicBool>,
    /// group -> (pinned replica, group batches in flight).  Entries exist
    /// only while a group has in-flight batches, so the map stays at the
    /// handful of currently-active routes.
    pins: Mutex<HashMap<(TaskId, PolicyId), (usize, usize)>>,
}

impl DispatchState {
    pub fn new(replicas: usize) -> Self {
        assert!(replicas > 0, "dispatch needs at least one replica");
        DispatchState {
            inflight: (0..replicas).map(|_| AtomicUsize::new(0)).collect(),
            dead: (0..replicas).map(|_| std::sync::atomic::AtomicBool::new(false)).collect(),
            pins: Mutex::new(HashMap::new()),
        }
    }

    pub fn replicas(&self) -> usize {
        self.inflight.len()
    }

    /// Batches submitted to `replica` and not yet completed.
    pub fn inflight(&self, replica: usize) -> usize {
        self.inflight[replica].load(Ordering::SeqCst)
    }

    pub fn alive(&self, replica: usize) -> bool {
        !self.dead[replica].load(Ordering::SeqCst)
    }

    /// Groups currently pinned to a replica (tests / introspection).
    pub fn pinned_groups(&self) -> usize {
        self.pins.lock().expect("dispatch pins").len()
    }

    /// Pick the replica for one batch of `key` and account it in flight:
    /// the pinned replica while the group already has batches in flight,
    /// else the live replica with the fewest in-flight batches (ties
    /// break to the lowest index; if every replica is dead the choice
    /// falls back to all of them — the submit will fail either way).
    pub fn assign(&self, key: (TaskId, PolicyId)) -> usize {
        let mut pins = self.pins.lock().expect("dispatch pins");
        let replica = match pins.get_mut(&key) {
            Some((replica, n)) => {
                *n += 1;
                *replica
            }
            None => {
                let replica = (0..self.inflight.len())
                    .filter(|r| self.alive(*r))
                    .min_by_key(|r| self.inflight[*r].load(Ordering::SeqCst))
                    .unwrap_or_else(|| {
                        (0..self.inflight.len())
                            .min_by_key(|r| self.inflight[*r].load(Ordering::SeqCst))
                            .expect("at least one replica")
                    });
                pins.insert(key, (replica, 1));
                replica
            }
        };
        // incremented under the pins lock so a concurrent completion
        // cannot interleave between replica choice and accounting
        self.inflight[replica].fetch_add(1, Ordering::SeqCst);
        replica
    }

    /// Mark one batch of `key` complete on `replica`; the group unpins
    /// (and may migrate on its next batch) when its last in-flight batch
    /// completes.  A completion whose group is no longer pinned to
    /// `replica` is stale — the replica died and `mark_dead` purged its
    /// pins — and is dropped without touching the live accounting.
    pub fn complete(&self, key: (TaskId, PolicyId), replica: usize) {
        let mut pins = self.pins.lock().expect("dispatch pins");
        match pins.get_mut(&key) {
            Some((r, n)) if *r == replica => {
                *n -= 1;
                if *n == 0 {
                    pins.remove(&key);
                }
                self.inflight[replica].fetch_sub(1, Ordering::SeqCst);
            }
            _ => {}
        }
    }

    /// Record that `replica`'s engine thread is gone: exclude it from
    /// future least-loaded choices and purge its pins so affected groups
    /// migrate on their next batch (their dead-queue batches can never
    /// complete; dropped completions surface as hangups upstream).
    pub fn mark_dead(&self, replica: usize) {
        self.dead[replica].store(true, Ordering::SeqCst);
        let mut pins = self.pins.lock().expect("dispatch pins");
        pins.retain(|_, (r, _)| *r != replica);
        // its queued batches can never complete and their stale
        // completions are dropped, so zero the counter — introspection
        // and the all-dead fallback must not see phantom in-flight work
        self.inflight[replica].store(0, Ordering::SeqCst);
    }
}

/// N engine replicas behind a load-aware dispatcher (DESIGN.md §5.7).
/// Startup fans the shared-read `preload` out to all replica threads
/// concurrently (each uploads to its own device context and compiles its
/// own executables — PJRT handles are not `Send`); shutdown stops every
/// replica first, then joins them in replica order.
pub struct EnginePool {
    /// Dropped in declaration order: each `Engine::drop` joins its
    /// (already stopped) thread, so shutdown joins replicas 0..N in order.
    replicas: Vec<Engine>,
    state: Arc<DispatchState>,
}

impl EnginePool {
    /// Spawn `options.replicas` engine threads.  All replicas start
    /// concurrently (checkpoint upload + executable precompile overlap
    /// across threads) and share one read-only preload set; the call
    /// returns once every replica reports ready, or the first error.
    pub fn spawn(
        artifacts: PathBuf,
        preload: Vec<(String, String, Container)>,
        precompile: Vec<(String, usize, usize)>,
        pool: Arc<ThreadPool>,
        staging: Arc<StagingPool>,
        options: EngineOptions,
    ) -> Result<EnginePool> {
        let n = options.replicas.max(1);
        let preload = Arc::new(preload);
        let pending: Vec<PendingEngine> = (0..n)
            .map(|i| {
                Engine::spawn_replica(
                    artifacts.clone(),
                    Arc::clone(&preload),
                    precompile.clone(),
                    Arc::clone(&pool),
                    Arc::clone(&staging),
                    options.clone(),
                    i,
                )
            })
            .collect::<Result<_>>()?;
        // wait in replica order; if one fails, dropping the remaining
        // pending handles closes their channels and the threads exit on
        // their own after startup
        let replicas = pending
            .into_iter()
            .map(PendingEngine::wait)
            .collect::<Result<Vec<_>>>()?;
        Ok(EnginePool { state: Arc::new(DispatchState::new(n)), replicas })
    }

    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// The pool's dispatch accounting (tests / introspection).
    pub fn dispatch_state(&self) -> &DispatchState {
        &self.state
    }

    /// Route one batch through the load-aware dispatcher.  The completion
    /// is wrapped so the in-flight accounting decrements exactly when the
    /// batch's completion runs.  A submit failure marks that replica dead
    /// (its pins are purged, making the failed attempt's wrapper a stale
    /// no-op) and the batch retries on the next live replica — one dead
    /// replica costs a re-route, not a batch of client errors.  `Err`
    /// means every replica is gone; the handed-back job's `done` must
    /// still be invoked exactly once (as `Coordinator::dispatch` does).
    pub fn submit(&self, job: InferJob) -> std::result::Result<(), Box<InferJob>> {
        let key = (job.task, job.policy);
        let mut job = job;
        for _ in 0..self.replicas.len() {
            let replica = self.state.assign(key);
            let state = Arc::clone(&self.state);
            let InferJob { task, policy, staging, cancel, done } = job;
            let wrapped = InferJob {
                task,
                policy,
                staging,
                cancel,
                done: Box::new(move |res| {
                    // decrement before the inner completion so a panicking
                    // callback (isolated by the worker pool) cannot leak a
                    // pin or an in-flight count.  After a failed attempt
                    // this is stale (the pin was purged by mark_dead) and
                    // complete() drops it.
                    state.complete(key, replica);
                    done(res);
                }),
            };
            match self.replicas[replica].submit(wrapped) {
                Ok(()) => return Ok(()),
                Err(boxed) => {
                    // the replica's engine thread is gone: exclude it
                    // from least-loaded choice (at zero in-flight it
                    // would win every tie) and retry the batch elsewhere
                    self.state.mark_dead(replica);
                    job = *boxed;
                }
            }
        }
        Err(Box::new(job))
    }

    pub fn task_id(&self, name: &str) -> Result<TaskId> {
        self.replicas[0].task_id(name)
    }

    pub fn mode_id(&self, name: &str) -> Result<ModeId> {
        self.replicas[0].mode_id(name)
    }

    pub fn policy_id(&self, name: &str) -> Result<PolicyId> {
        self.replicas[0].policy_id(name)
    }

    /// The mirrored policy-name table (identical across replicas: every
    /// replica derives it from the same `manifest.json`).
    pub fn policy_names(&self) -> &[String] {
        self.replicas[0].policy_names()
    }

    pub fn policy_exec_mode(&self, policy: PolicyId) -> Result<ModeId> {
        self.replicas[0].policy_exec_mode(policy)
    }

    // NB: no pool-level `infer_blocking` — blocking convenience calls go
    // through a single `Engine` (see `Engine::infer_blocking`); serving
    // traffic reaches the pool only via `Coordinator::dispatch`.
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        // stop every replica first so their queues drain concurrently;
        // the Vec drop then runs Engine::drop per replica, joining the
        // threads in replica order (deterministic shutdown)
        for e in &self.replicas {
            let _ = e.tx.send(Msg::Stop);
        }
    }
}

/// One launched-but-not-read-back batch (the pipeline register).
struct InFlight {
    pending: PendingOutputs,
    done: Completion,
    /// job receipt (before upload) — the `engine_us` clock.
    t_job: Instant,
    /// post-upload launch point — the `exec_us` clock.
    t0: Instant,
    upload_us: u64,
    exec_seq: u64,
}

/// Stage 3: synchronize, copy logits to host, and hand de-batching +
/// reply dispatch to the worker pool.
fn retire(rt: &Runtime, f: InFlight, pool: &ThreadPool, replica: usize) {
    let res = rt.readback_logits(f.pending).map(|logits| InferDone {
        logits,
        exec_us: f.t0.elapsed().as_micros() as u64,
        upload_us: f.upload_us,
        engine_us: f.t_job.elapsed().as_micros() as u64,
        replica,
        exec_seq: f.exec_seq,
    });
    let done = f.done;
    pool.spawn(move || done(res));
}

#[allow(clippy::too_many_arguments)]
fn engine_main(
    artifacts: PathBuf,
    preload: Arc<Vec<(String, String, Container)>>,
    precompile: Vec<(String, usize, usize)>,
    rx: Receiver<Msg>,
    ready_tx: Sender<Result<RouteTables>>,
    pool: Arc<ThreadPool>,
    staging: Arc<StagingPool>,
    options: EngineOptions,
    replica: usize,
) {
    let mut rt = match Manifest::load(&artifacts).and_then(Runtime::new) {
        Ok(rt) => rt,
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let mut init = || -> Result<RouteTables> {
        for (task, mode, ckpt) in preload.iter() {
            rt.upload_checkpoint(task, mode, ckpt)?;
        }
        for (mode, seq, bucket) in &precompile {
            rt.model_exe(mode, *seq, *bucket)?;
        }
        let man = &rt.manifest;
        Ok(RouteTables {
            tasks: man.task_order.clone(),
            modes: man.mode_order.clone(),
            policies: man.policy_order.clone(),
            policy_exec: man
                .policy_order
                .iter()
                .map(|p| man.policies[p].exec_mode)
                .collect(),
        })
    };
    let tables = match init() {
        Ok(t) => t,
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    // keep the engine thread's own copy of executable selection
    let policy_exec = tables.policy_exec.clone();
    if ready_tx.send(Ok(tables)).is_err() {
        return;
    }

    let mut inflight: Option<InFlight> = None;
    // per-replica batch serial, stamped in execution order (the
    // cross-replica FIFO witness carried on InferDone::exec_seq)
    let mut next_exec_seq: u64 = 0;
    loop {
        // With a batch executing, prefer new work (to keep the device fed)
        // but retire the head batch as soon as the queue runs dry.
        let msg = if inflight.is_some() {
            match rx.try_recv() {
                Ok(m) => Some(m),
                Err(TryRecvError::Empty) => {
                    if let Some(f) = inflight.take() {
                        retire(&rt, f, &pool, replica);
                    }
                    rx.recv().ok()
                }
                Err(TryRecvError::Disconnected) => None,
            }
        } else {
            rx.recv().ok()
        };
        let job = match msg {
            Some(Msg::Infer(job)) => *job,
            Some(Msg::Stop) | None => break,
        };

        let InferJob { task, policy, staging: host, cancel, done } = job;
        // test-only service-rate throttle (deterministic overload tests)
        if let Some(d) = options.throttle {
            std::thread::sleep(d);
        }
        // Cancel-before-submit hook: the one cancellation point past
        // batch formation, strictly before any device work.  Cancelled
        // jobs consume no exec_seq — the per-replica serial witnesses
        // *executed* batches only.
        if matches!(&cancel, Some(c) if c()) {
            staging.put(host);
            pool.spawn(move || done(Err(anyhow::Error::new(CancelledBeforeSubmit))));
            continue;
        }
        let exec_seq = next_exec_seq;
        next_exec_seq += 1;
        // Executable selection: policy -> mode through the mirrored table.
        let mode = match policy_exec.get(policy.index()) {
            Some(m) => *m,
            None => {
                staging.put(host);
                pool.spawn(move || done(Err(anyhow!("PolicyId {} out of range", policy.0))));
                continue;
            }
        };
        let t_job = Instant::now();
        // Stage 1: upload this batch's inputs (overlaps the previous
        // batch's device execution), then recycle the host buffers.  The
        // staging buffer carries its seq bucket, so a short batch uploads
        // `bucket * seq_bucket` tokens, not `bucket * max_seq`.
        let uploaded =
            rt.upload_inputs(host.seq, host.bucket, &host.ids, &host.type_ids, &host.mask);
        let upload_us = t_job.elapsed().as_micros() as u64;
        staging.put(host);
        let inputs = match uploaded {
            Ok(i) => i,
            Err(e) => {
                if let Some(f) = inflight.take() {
                    retire(&rt, f, &pool, replica);
                }
                pool.spawn(move || done(Err(e)));
                continue;
            }
        };
        // Stage 2: launch this batch.  The exec clock starts only after
        // the upload returned: InferDone::exec_us must not double-count
        // upload_us (it used to, inflating per-batch exec reporting).
        let t0 = Instant::now();
        let launched = rt.execute_model(task, mode, &inputs);
        // Stage 3 for the previous batch: its readback now overlaps this
        // batch's execution.
        if let Some(f) = inflight.take() {
            retire(&rt, f, &pool, replica);
        }
        match launched {
            Ok(pending) => {
                let f = InFlight { pending, done, t_job, t0, upload_us, exec_seq };
                if options.overlap {
                    inflight = Some(f);
                } else {
                    retire(&rt, f, &pool, replica);
                }
            }
            Err(e) => {
                pool.spawn(move || done(Err(e)));
            }
        }
    }
    if let Some(f) = inflight.take() {
        retire(&rt, f, &pool, replica);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{forall, Rng};

    fn key(task: u16, policy: u16) -> (TaskId, PolicyId) {
        (TaskId(task), PolicyId(policy))
    }

    #[test]
    fn dispatch_pins_group_while_in_flight() {
        let d = DispatchState::new(2);
        let g0 = key(0, 0);
        let g1 = key(0, 1);
        // first assignment: tie at zero load -> lowest index
        assert_eq!(d.assign(g0), 0);
        // pinned while in flight, even though replica 1 is emptier
        assert_eq!(d.assign(g0), 0);
        assert_eq!(d.inflight(0), 2);
        assert_eq!(d.inflight(1), 0);
        // a different group routes to the least-loaded replica
        assert_eq!(d.assign(g1), 1);
        assert_eq!(d.pinned_groups(), 2);
        // draining one batch keeps the pin; draining all releases it
        d.complete(g0, 0);
        assert_eq!(d.assign(g0), 0, "still one batch in flight: pinned");
        d.complete(g0, 0);
        d.complete(g0, 0);
        assert_eq!(d.pinned_groups(), 1);
        assert_eq!(d.inflight(0), 0);
        // migration: replica 1 carries g1's batch, so g0 re-pins to 0 —
        // but if 0 were loaded it could move (see prop test)
        assert_eq!(d.assign(g0), 0);
        d.complete(g1, 1);
        d.complete(g0, 0);
        assert_eq!(d.pinned_groups(), 0);
    }

    #[test]
    fn dispatch_migrates_drained_group_off_loaded_replica() {
        let d = DispatchState::new(2);
        let g0 = key(0, 0);
        let g1 = key(1, 0);
        // g0 runs a batch on replica 0 and drains
        assert_eq!(d.assign(g0), 0);
        d.complete(g0, 0);
        assert_eq!(d.pinned_groups(), 0);
        // g1 now occupies replica 0 (tie at zero load -> lowest index)
        assert_eq!(d.assign(g1), 0);
        // g0 returns while replica 0 is loaded: it migrates to replica 1
        // — pinning is per in-flight window, not a permanent assignment
        assert_eq!(d.assign(g0), 1);
        d.complete(g1, 0);
        d.complete(g0, 1);
        assert_eq!(d.pinned_groups(), 0);
        assert_eq!(d.inflight(0) + d.inflight(1), 0);
    }

    #[test]
    fn dead_replica_is_excluded_and_its_groups_migrate() {
        let d = DispatchState::new(2);
        let g0 = key(0, 0);
        let g1 = key(0, 1);
        assert_eq!(d.assign(g0), 0);
        d.mark_dead(0);
        assert!(!d.alive(0));
        // pins on the dead replica are purged and its counter zeroed (the
        // queued batch can never complete): g0's next batch migrates
        assert_eq!(d.pinned_groups(), 0);
        assert_eq!(d.inflight(0), 0);
        assert_eq!(d.assign(g0), 1);
        // the dead replica never wins least-loaded again, even though
        // its in-flight count is the minimum
        assert_eq!(d.assign(g1), 1);
        // a stale completion from the dead replica is dropped: g0 is now
        // pinned to replica 1, so (g0, 0) matches nothing
        d.complete(g0, 0);
        assert_eq!(d.inflight(1), 2);
        assert_eq!(d.pinned_groups(), 2);
        d.complete(g0, 1);
        d.complete(g1, 1);
        assert_eq!(d.pinned_groups(), 0);
        assert_eq!(d.inflight(1), 0);
    }

    #[test]
    fn prop_per_group_fifo_pinning_and_count_consistency() {
        forall("dispatch-pinning", 60, |r: &mut Rng| {
            let nrep = 1 + r.below(4);
            let d = DispatchState::new(nrep);
            // in-flight batches as (group, replica-it-was-assigned)
            let mut open: Vec<((TaskId, PolicyId), usize)> = Vec::new();
            let mut pinned: HashMap<(TaskId, PolicyId), usize> = HashMap::new();
            for _ in 0..200 {
                if open.is_empty() || r.bool() {
                    let k = key(r.below(2) as u16, r.below(3) as u16);
                    let loads: Vec<usize> = (0..nrep).map(|i| d.inflight(i)).collect();
                    let rep = d.assign(k);
                    assert!(rep < nrep);
                    match pinned.get(&k) {
                        // the FIFO guarantee: while a group has batches in
                        // flight, every new batch lands on the same replica
                        Some(p) => assert_eq!(*p, rep, "group reassigned while in flight"),
                        // a fresh (or migrated) group takes a least-loaded
                        // replica, measured before this assignment
                        None => {
                            let min = loads.iter().copied().min().unwrap();
                            assert_eq!(loads[rep], min, "not least-loaded: {loads:?} -> {rep}");
                            pinned.insert(k, rep);
                        }
                    }
                    open.push((k, rep));
                } else {
                    let i = r.below(open.len());
                    let (k, rep) = open.swap_remove(i);
                    d.complete(k, rep);
                    if !open.iter().any(|(ok, _)| *ok == k) {
                        pinned.remove(&k);
                    }
                }
                // accounting consistency: per-replica in-flight counters
                // always equal the number of open batches per replica
                for rep in 0..nrep {
                    assert_eq!(
                        d.inflight(rep),
                        open.iter().filter(|(_, p)| *p == rep).count(),
                        "replica {rep} count drifted"
                    );
                }
                assert_eq!(d.pinned_groups(), pinned.len());
            }
            for (k, rep) in open.drain(..) {
                d.complete(k, rep);
            }
            assert_eq!(d.pinned_groups(), 0);
            for rep in 0..nrep {
                assert_eq!(d.inflight(rep), 0);
            }
        });
    }
}
