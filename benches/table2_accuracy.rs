//! Table 2: SynGLUE validation accuracy per quantization mode — the
//! paper's headline evaluation, regenerated end-to-end in rust (calibrate
//! -> fold+quantize -> INT8 inference via PJRT -> metrics).
//!
//! Env: ZQH_CALIB (default 100), ZQH_TASKS (csv), ZQH_MODES (csv).

use zqhero::bench::Table;
use zqhero::evalharness as eh;
use zqhero::model::manifest::Manifest;
use zqhero::runtime::Runtime;

fn main() {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("table2_accuracy: run `make artifacts` first");
        return;
    }
    let calib: usize = std::env::var("ZQH_CALIB").ok().and_then(|s| s.parse().ok()).unwrap_or(100);
    let man = Manifest::load(&dir).expect("manifest");
    let tasks: Vec<String> = std::env::var("ZQH_TASKS")
        .map(|s| s.split(',').map(str::to_string).collect())
        .unwrap_or_else(|_| man.task_order.clone());
    let modes: Vec<String> = std::env::var("ZQH_MODES")
        .map(|s| s.split(',').map(str::to_string).collect())
        .unwrap_or_else(|_| man.mode_order.clone());

    let mut rt = Runtime::new(man).expect("runtime");
    let t0 = std::time::Instant::now();
    let results = eh::table2(&mut rt, &tasks, &modes, calib, 100.0, |mode, task| {
        eprintln!("  [table2] {mode} / {task} ({:.0}s)", t0.elapsed().as_secs_f64());
    })
    .expect("table2");

    println!("\nTable 2: ZeroQuant-HERO on SynGLUE (validation), calib={calib} batches x 16\n");
    let mut headers = vec!["Mode".to_string()];
    headers.extend(tasks.iter().map(|t| eh::paper_header(t).to_string()));
    let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut table = Table::new(&hrefs);
    for mode in &modes {
        let mut row = vec![eh::mode_label(mode)];
        for t in &tasks {
            row.push(eh::paper_cell(t, &results[mode][t]));
        }
        table.row(row);
    }
    table.print();

    // shape checks vs the paper: quantized modes track FP except the
    // sensitive task at the deepest mode (CoLA-like Mcc drop at M3).
    if modes.iter().any(|m| m == "fp") && modes.iter().any(|m| m == "m1") {
        let mut worst: (String, f64) = (String::new(), 0.0);
        for t in &tasks {
            let fp_first = results["fp"][t].values().next().copied().unwrap_or(0.0);
            let m1_first = results["m1"][t].values().next().copied().unwrap_or(0.0);
            let drop = fp_first - m1_first;
            if drop > worst.1 {
                worst = (t.clone(), drop);
            }
        }
        println!("\nlargest FP->M1 drop: {} ({:.2} pts)", worst.0, worst.1 * 100.0);
    }
    println!("total: {:.0}s", t0.elapsed().as_secs_f64());
}
