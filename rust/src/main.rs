//! `repro` — ZeroQuant-HERO leader binary: PTQ pipeline (calibrate →
//! quantize → eval) and the serving coordinator, over AOT HLO artifacts.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use zqhero::bench::Table;
use zqhero::cli::{Cli, OptSpec, SubSpec};
use zqhero::coordinator::{Coordinator, ServerConfig};
use zqhero::evalharness as eh;
use zqhero::model::manifest::Manifest;

use zqhero::perfmodel;
use zqhero::runtime::{FaultKind, FaultPlan, FaultSpec, RestartPolicy, Runtime};
use zqhero::traceflow;

fn artifacts_opt() -> OptSpec {
    OptSpec {
        name: "artifacts",
        takes_value: true,
        default: Some("artifacts"),
        help: "artifacts directory (make artifacts)",
    }
}

fn cli() -> Cli {
    Cli {
        bin: "repro",
        about: "ZeroQuant-HERO: hardware-enhanced W8A8 PTQ framework (paper reproduction)",
        subs: vec![
            SubSpec {
                name: "info",
                help: "print manifest / artifact summary",
                opts: vec![artifacts_opt()],
            },
            SubSpec {
                name: "calibrate",
                help: "run calibration forward passes (paper: 100 batches x 16)",
                opts: vec![
                    artifacts_opt(),
                    OptSpec { name: "task", takes_value: true, default: None, help: "task name (omit for all)" },
                    OptSpec { name: "batches", takes_value: true, default: Some("100"), help: "calibration batches" },
                    OptSpec { name: "force", takes_value: false, default: None, help: "recalibrate even if cached" },
                ],
            },
            SubSpec {
                name: "quantize",
                help: "fold + quantize fp32 checkpoints into HERO checkpoints",
                opts: vec![
                    artifacts_opt(),
                    OptSpec { name: "task", takes_value: true, default: None, help: "task name (omit for all)" },
                    OptSpec { name: "mode", takes_value: true, default: None, help: "m1|m2|m3 (omit for all)" },
                    OptSpec { name: "pct", takes_value: true, default: Some("100"), help: "percentile clip for scales" },
                    OptSpec { name: "calib-batches", takes_value: true, default: Some("100"), help: "batches to use" },
                ],
            },
            SubSpec {
                name: "eval",
                help: "regenerate Table 2 (accuracy per task x mode)",
                opts: vec![
                    artifacts_opt(),
                    OptSpec { name: "task", takes_value: true, default: None, help: "task (omit for all)" },
                    OptSpec { name: "mode", takes_value: true, default: None, help: "fp|m1|m2|m3 (omit for all)" },
                    OptSpec { name: "calib-batches", takes_value: true, default: Some("100"), help: "calibration batches" },
                    OptSpec { name: "pct", takes_value: true, default: Some("100"), help: "percentile clip" },
                ],
            },
            SubSpec {
                name: "trace",
                help: "print Fig.1/Fig.2 precision-flow and verify vs HLO",
                opts: vec![
                    artifacts_opt(),
                    OptSpec { name: "mode", takes_value: true, default: None, help: "mode to trace (default: the manifest's first mode)" },
                ],
            },
            SubSpec {
                name: "perfmodel",
                help: "analytic A100 projection (hardware-enhanced claims)",
                opts: vec![
                    OptSpec { name: "batch", takes_value: true, default: Some("16"), help: "batch size" },
                    OptSpec { name: "seq", takes_value: true, default: Some("128"), help: "sequence length" },
                ],
            },
            SubSpec {
                name: "serve",
                help: "serve newline-delimited JSON requests over TCP",
                opts: vec![
                    artifacts_opt(),
                    OptSpec { name: "host", takes_value: true, default: Some("127.0.0.1"), help: "bind host" },
                    OptSpec { name: "port", takes_value: true, default: Some("7433"), help: "bind port" },
                    OptSpec { name: "tasks", takes_value: true, default: Some("sst2,mrpc,cola"), help: "tasks to load" },
                    OptSpec { name: "modes", takes_value: true, default: Some("fp,m1,m2,m3"), help: "precision modes to load" },
                    OptSpec { name: "policies", takes_value: true, default: None, help: "extra manifest policies to load (comma-separated)" },
                    OptSpec { name: "max-batch", takes_value: true, default: Some("16"), help: "batcher max batch" },
                    OptSpec { name: "max-wait-ms", takes_value: true, default: Some("4"), help: "batcher max wait" },
                    OptSpec { name: "replicas", takes_value: true, default: Some("1"), help: "engine replicas behind the load-aware dispatcher" },
                    OptSpec { name: "queue-cap", takes_value: true, default: Some("1024"), help: "admission queue bound (submit sheds with busy beyond it)" },
                    OptSpec { name: "default-deadline-ms", takes_value: true, default: Some("0"), help: "deadline for requests that carry none (0 = never expire)" },
                    OptSpec { name: "governor", takes_value: false, default: None, help: "enable the load-adaptive precision governor" },
                    OptSpec { name: "watchdog-ms", takes_value: true, default: Some("0"), help: "replica heartbeat stall budget before supervised restart (0 = off)" },
                    OptSpec { name: "restart-budget", takes_value: true, default: Some("5"), help: "replica restarts tolerated per window before circuit-breaker exclusion" },
                    OptSpec { name: "max-resident-cells", takes_value: true, default: Some("0"), help: "LRU budget for resident executable cells per replica (0 = unbounded)" },
                    OptSpec { name: "pin-full-grid", takes_value: false, default: None, help: "pin every (mode, seq, batch) executable cell at startup (pre-residency eager preload)" },
                    OptSpec { name: "reload", takes_value: false, default: None, help: "hot-reload the manifest when artifacts/manifest.json changes on disk (SIGHUP also triggers a reload)" },
                    OptSpec { name: "nodes", takes_value: true, default: None, help: "comma-separated engine-node addresses (host:port): serve as a front-end tier routing over the v2 link protocol instead of running an in-process engine" },
                ],
            },
            SubSpec {
                name: "engine-node",
                help: "engine-node tier: the coordinator (engine pool + residency manager) behind a length-delimited v2 link listener for a front end (DESIGN.md 5.14)",
                opts: vec![
                    artifacts_opt(),
                    OptSpec { name: "host", takes_value: true, default: Some("127.0.0.1"), help: "bind host" },
                    OptSpec { name: "port", takes_value: true, default: Some("7434"), help: "bind port (0 = ephemeral)" },
                    OptSpec { name: "tasks", takes_value: true, default: Some("sst2,mrpc,cola"), help: "tasks to load" },
                    OptSpec { name: "modes", takes_value: true, default: Some("fp,m1,m2,m3"), help: "precision modes to load" },
                    OptSpec { name: "policies", takes_value: true, default: None, help: "extra manifest policies to load (comma-separated)" },
                    OptSpec { name: "max-batch", takes_value: true, default: Some("16"), help: "batcher max batch" },
                    OptSpec { name: "max-wait-ms", takes_value: true, default: Some("4"), help: "batcher max wait" },
                    OptSpec { name: "replicas", takes_value: true, default: Some("1"), help: "engine replicas behind the load-aware dispatcher" },
                    OptSpec { name: "queue-cap", takes_value: true, default: Some("1024"), help: "node-local admission bound (sheds with a typed busy frame beyond it)" },
                    OptSpec { name: "watchdog-ms", takes_value: true, default: Some("0"), help: "replica heartbeat stall budget before supervised restart (0 = off)" },
                    OptSpec { name: "restart-budget", takes_value: true, default: Some("5"), help: "replica restarts tolerated per window before circuit-breaker exclusion" },
                    OptSpec { name: "max-resident-cells", takes_value: true, default: Some("0"), help: "LRU budget for resident executable cells per replica (0 = unbounded)" },
                    OptSpec { name: "fake-engine-ms", takes_value: true, default: Some("0"), help: "serve a fake engine with this per-batch latency instead of real executables (testing; 0 = real engine)" },
                ],
            },
            SubSpec {
                name: "serve-bench",
                help: "closed-loop serving benchmark through the coordinator",
                opts: vec![
                    artifacts_opt(),
                    OptSpec { name: "tasks", takes_value: true, default: Some("sst2"), help: "comma-separated tasks" },
                    OptSpec { name: "modes", takes_value: true, default: Some("fp,m3"), help: "comma-separated modes" },
                    OptSpec { name: "policies", takes_value: true, default: None, help: "extra manifest policies to sweep (comma-separated)" },
                    OptSpec { name: "requests", takes_value: true, default: Some("256"), help: "requests per (task,mode)" },
                    OptSpec { name: "concurrency", takes_value: true, default: Some("32"), help: "in-flight requests" },
                    OptSpec { name: "max-batch", takes_value: true, default: Some("16"), help: "batcher max batch" },
                    OptSpec { name: "max-wait-ms", takes_value: true, default: Some("4"), help: "batcher max wait" },
                    OptSpec { name: "replicas", takes_value: true, default: Some("1"), help: "engine replicas behind the load-aware dispatcher" },
                    OptSpec { name: "queue-cap", takes_value: true, default: Some("256"), help: "admission queue bound (submit sheds with busy beyond it)" },
                    OptSpec { name: "default-deadline-ms", takes_value: true, default: Some("0"), help: "deadline for requests that carry none (0 = never expire)" },
                    OptSpec { name: "governor", takes_value: false, default: None, help: "enable the load-adaptive precision governor" },
                    OptSpec { name: "overload", takes_value: true, default: Some("0"), help: "open-loop overload burst at X times measured capacity (0 = closed loop)" },
                    OptSpec { name: "mixed-length", takes_value: false, default: None, help: "length-aware smoke: drive real-length rows vs a padded baseline, write BENCH_seq_buckets_smoke.json" },
                    OptSpec { name: "watchdog-ms", takes_value: true, default: Some("0"), help: "replica heartbeat stall budget before supervised restart (0 = off)" },
                    OptSpec { name: "restart-budget", takes_value: true, default: Some("5"), help: "replica restarts tolerated per window before circuit-breaker exclusion" },
                    OptSpec { name: "chaos", takes_value: false, default: None, help: "supervision smoke: kill one replica mid-run, assert goodput recovers, write BENCH_chaos_smoke.json" },
                    OptSpec { name: "residency", takes_value: false, default: None, help: "residency smoke: pin-set startup vs eager full-grid preload, write BENCH_residency.json" },
                    OptSpec { name: "max-resident-cells", takes_value: true, default: Some("0"), help: "LRU budget for resident executable cells per replica (0 = unbounded)" },
                    OptSpec { name: "nodes", takes_value: true, default: Some("0"), help: "multi-host sweep: open-loop goodput through a front end over 1..N fake-engine nodes, write BENCH_multihost.json (0 = off; self-contained, no artifacts needed)" },
                ],
            },
            SubSpec {
                name: "lint",
                help: "herolint: lock-order / atomic-ordering / panic-path / ledger static analyses over the source tree (DESIGN.md 5.11)",
                opts: vec![
                    OptSpec { name: "src", takes_value: true, default: Some("src"), help: "source root to lint (relative to the cargo workspace)" },
                    OptSpec { name: "json", takes_value: false, default: None, help: "machine-readable report on stdout" },
                ],
            },
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli().parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let r = match args.subcommand.as_str() {
        "info" => cmd_info(&args),
        "calibrate" => cmd_calibrate(&args),
        "quantize" => cmd_quantize(&args),
        "eval" => cmd_eval(&args),
        "trace" => cmd_trace(&args),
        "perfmodel" => cmd_perfmodel(&args),
        "serve" => cmd_serve(&args),
        "engine-node" => cmd_engine_node(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "lint" => cmd_lint(&args),
        _ => unreachable!(),
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &zqhero::cli::Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn task_list(man: &Manifest, args: &zqhero::cli::Args) -> Vec<String> {
    match args.get("task") {
        Some(t) => vec![t.to_string()],
        None => man.task_order.clone(),
    }
}

/// Resolve an optional `--mode` flag: validated against the manifest (so
/// a bad name fails with the known-mode list), defaulting to the
/// manifest's first mode — never a hardcoded name.
fn default_mode(man: &Manifest, flag: Option<&str>) -> Result<String> {
    match flag {
        Some(m) => man.mode_id(m).map(|_| m.to_string()),
        None => man
            .mode_order
            .first()
            .cloned()
            .context("manifest declares no modes"),
    }
}

fn cmd_info(args: &zqhero::cli::Args) -> Result<()> {
    let man = Manifest::load(&artifacts_dir(args))?;
    let m = &man.model;
    println!("ZeroQuant-HERO artifacts @ {}", man.root.display());
    println!(
        "model: {} layers, d={}, heads={}, ffn={}, vocab={}, seq={}",
        m.layers, m.hidden, m.heads, m.ffn, m.vocab_size, man.seq
    );
    println!("buckets: {:?} x seq_buckets {:?}", man.buckets, man.seq_buckets);
    let mut t = Table::new(&["mode", "Emb", "QKV", "Attn", "AttnOut", "FC1", "FC2", "params"]);
    for name in &man.mode_order {
        let spec = &man.modes[name];
        let r = spec.switches.row();
        let c = |b: bool| if b { "INT8" } else { "FP" }.to_string();
        t.row(vec![
            eh::mode_label(name),
            c(r[0]), c(r[1]), c(r[2]), c(r[3]), c(r[4]), c(r[5]),
            spec.params.len().to_string(),
        ]);
    }
    t.print();
    println!("\ntasks:");
    for name in &man.task_order {
        let task = &man.tasks[name];
        println!(
            "  {:6} classes={} metrics={:?} splits={:?}",
            name, task.classes, task.metrics,
            task.splits.keys().collect::<Vec<_>>()
        );
    }
    Ok(())
}

fn cmd_calibrate(args: &zqhero::cli::Args) -> Result<()> {
    let man = Manifest::load(&artifacts_dir(args))?;
    let batches = args.get_usize("batches")?.unwrap_or(100);
    let force = args.get_bool("force");
    let mut rt = Runtime::new(man)?;
    for tname in task_list(&rt.manifest, args) {
        let task = rt.manifest.task(&tname)?.clone();
        let t0 = Instant::now();
        let hist = eh::ensure_calibration(&mut rt, &task, batches, force)?;
        println!(
            "[calibrate] {tname}: {} batches x {} ({}s)",
            hist[0].1.len(),
            rt.manifest.calib.batch,
            t0.elapsed().as_secs()
        );
    }
    Ok(())
}

fn cmd_quantize(args: &zqhero::cli::Args) -> Result<()> {
    let man = Manifest::load(&artifacts_dir(args))?;
    let pct = args.get_f64("pct")?.unwrap_or(100.0);
    let batches = args.get_usize("calib-batches")?.unwrap_or(100);
    let modes: Vec<String> = match args.get("mode") {
        Some(m) => vec![m.to_string()],
        None => man.mode_order.iter().filter(|m| *m != "fp").cloned().collect(),
    };
    let mut rt = Runtime::new(man)?;
    for tname in task_list(&rt.manifest, args) {
        let task = rt.manifest.task(&tname)?.clone();
        let hist = eh::ensure_calibration(&mut rt, &task, batches, false)?;
        for mode in &modes {
            let ckpt = eh::quantize_task(&mut rt, &task, mode, &hist, pct, None)?;
            let int8: usize = ckpt
                .entries
                .iter()
                .filter(|(_, t)| t.dtype() == zqhero::model::DType::I8)
                .map(|(_, t)| t.numel())
                .sum();
            println!(
                "[quantize] {tname} {mode}: {} tensors, {} int8 weights (pct={pct})",
                ckpt.len(),
                int8
            );
        }
    }
    Ok(())
}

fn cmd_eval(args: &zqhero::cli::Args) -> Result<()> {
    let man = Manifest::load(&artifacts_dir(args))?;
    let pct = args.get_f64("pct")?.unwrap_or(100.0);
    let batches = args.get_usize("calib-batches")?.unwrap_or(100);
    let modes: Vec<String> = match args.get("mode") {
        Some(m) => vec![m.to_string()],
        None => man.mode_order.clone(),
    };
    let tasks = task_list(&man, args);
    let mut rt = Runtime::new(man)?;
    let t0 = Instant::now();
    let results = eh::table2(&mut rt, &tasks, &modes, batches, pct, |mode, task| {
        eprintln!("  [eval] {mode} / {task} ...");
    })?;

    // Table 2, paper layout
    let mut headers = vec!["Mode".to_string()];
    headers.extend(tasks.iter().map(|t| eh::paper_header(t).to_string()));
    let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut table = Table::new(&hrefs);
    for mode in &modes {
        let mut row = vec![eh::mode_label(mode)];
        for t in &tasks {
            row.push(eh::paper_cell(t, &results[mode][t]));
        }
        table.row(row);
    }
    println!("\nTable 2 (SynGLUE validation; paper layout):");
    table.print();
    println!("total eval time: {:.0}s", t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_trace(args: &zqhero::cli::Args) -> Result<()> {
    let man = Manifest::load(&artifacts_dir(args))?;
    // route defaults come from the manifest, never a hardcoded name; a bad
    // --mode fails with the known-mode list (Manifest::mode_id shape)
    let mode = default_mode(&man, args.get("mode"))?;
    let spec = man.mode(&mode)?;
    println!("== Figure 1: attention module precision flow ({}) ==", eh::mode_label(&mode));
    let mut t = Table::new(&["tensor", "producer", "scheme", "dtype"]);
    for r in traceflow::attention_flow(&spec.switches) {
        t.row(vec![r.tensor.into(), r.producer.into(), r.scheme, r.dtype]);
    }
    t.print();
    println!("\n== Figure 2: MLP module precision flow ==");
    let mut t = Table::new(&["tensor", "producer", "scheme", "dtype"]);
    for r in traceflow::mlp_flow(&spec.switches) {
        t.row(vec![r.tensor.into(), r.producer.into(), r.scheme, r.dtype]);
    }
    t.print();

    let bucket = *man.buckets.last().context("buckets")?;
    let (expected, found) = traceflow::verify_mode_artifact(&man, &mode, bucket)?;
    println!("\nHLO verification (b{bucket}): expected {expected} int8 GeMMs, found {found}");
    anyhow::ensure!(expected == found, "artifact does not match Table 1 claims");
    println!("OK — artifact matches the Table 1 row.");
    Ok(())
}

fn cmd_perfmodel(args: &zqhero::cli::Args) -> Result<()> {
    let batch = args.get_usize("batch")?.unwrap_or(16);
    let seq = args.get_usize("seq")?.unwrap_or(128);
    let cfg = perfmodel::bert_base();
    println!("A100 analytic projection, BERT_base, batch={batch} seq={seq}");
    let mut t = Table::new(&["mode", "proj time (us)", "speedup vs FP16"]);
    let modes = [
        ("FP16", "000000"),
        ("HERO-M1", "110010"),
        ("HERO-M2", "111110"),
        ("HERO-M3", "111111"),
    ];
    let fp_t = perfmodel::model_time_us(&cfg, &tag_to_switches("000000"), batch, seq);
    for (label, tag) in modes {
        let t_us = perfmodel::model_time_us(&cfg, &tag_to_switches(tag), batch, seq);
        t.row(vec![label.into(), format!("{t_us:.0}"), format!("{:.2}x", fp_t / t_us)]);
    }
    t.print();
    Ok(())
}

fn tag_to_switches(tag: &str) -> zqhero::model::Switches {
    let b: Vec<bool> = tag.chars().map(|c| c == '1').collect();
    zqhero::model::Switches {
        embedding: b[0],
        qkv: b[1],
        attn: b[2],
        attn_output: b[3],
        fc1: b[4],
        fc2: b[5],
    }
}

/// Routes = tasks x (modes + policies), where each route name is
/// validated against the manifest and policies resolve to the executable
/// mode whose checkpoint must exist on disk.
fn route_names(man: &Manifest, args: &zqhero::cli::Args, default_modes: &str) -> Result<Vec<String>> {
    let mut names: Vec<String> = args
        .get_or("modes", default_modes)
        .split(',')
        .map(str::to_string)
        .collect();
    if let Some(ps) = args.get("policies") {
        names.extend(ps.split(',').map(str::to_string));
    }
    for n in &names {
        man.policy(n)?; // fail early with the known-policy list
    }
    Ok(names)
}

/// Quantize any missing checkpoint for the executable modes behind the
/// given route names (offline PTQ prep).  With the governor enabled the
/// degradation-chain targets of every route are prepped too — the
/// coordinator preloads them at start and must find them on disk.
fn ensure_route_checkpoints(
    dir: &std::path::Path,
    tasks: &[String],
    routes: &[String],
    governor: bool,
) -> Result<()> {
    let man = Manifest::load(dir)?;
    let mut rt = Runtime::new(man)?;
    let mut modes: Vec<String> = Vec::new();
    for r in routes {
        let spec = rt.manifest.policy(r)?;
        modes.push(rt.manifest.mode_name(spec.exec_mode).to_string());
        if governor {
            let pid = rt.manifest.policy_id(r)?;
            for step in rt.manifest.downgrade_chain(pid) {
                let exec = rt.manifest.policy_by_id(step).exec_mode;
                modes.push(rt.manifest.mode_name(exec).to_string());
            }
        }
    }
    modes.sort();
    modes.dedup();
    for t in tasks {
        let task = rt.manifest.task(t)?.clone();
        for m in &modes {
            if m == "fp" {
                continue;
            }
            let rel = task.checkpoint_rel(m);
            if !rt.manifest.path(&rel).exists() {
                eprintln!("[prep] quantizing {t}/{m}...");
                let hist = eh::ensure_calibration(&mut rt, &task, 100, false)?;
                eh::quantize_task(&mut rt, &task, m, &hist, 100.0, None)?;
            }
        }
    }
    Ok(())
}

/// Shared overload-control knobs of `serve` / `serve-bench`.
fn overload_config(args: &zqhero::cli::Args) -> Result<(usize, Option<Duration>, bool)> {
    let queue_cap = args.get_usize("queue-cap")?.unwrap_or(1024).max(1);
    let default_deadline = match args.get_usize("default-deadline-ms")?.unwrap_or(0) {
        0 => None,
        ms => Some(Duration::from_millis(ms as u64)),
    };
    Ok((queue_cap, default_deadline, args.get_bool("governor")))
}

/// Shared replica-supervision knobs of `serve` / `serve-bench`
/// (DESIGN.md §5.10): the watchdog's heartbeat stall budget and the
/// circuit breaker's restart budget.
fn supervision_config(args: &zqhero::cli::Args) -> Result<(Option<Duration>, RestartPolicy)> {
    let watchdog = match args.get_usize("watchdog-ms")?.unwrap_or(0) {
        0 => None,
        ms => Some(Duration::from_millis(ms as u64)),
    };
    let budget = args.get_usize("restart-budget")?.unwrap_or(5).max(1);
    Ok((watchdog, RestartPolicy { budget, ..RestartPolicy::default() }))
}

/// Parse `--max-resident-cells` (0 = unbounded) into the LRU budget.
fn residency_budget(args: &zqhero::cli::Args) -> Result<Option<usize>> {
    Ok(match args.get_usize("max-resident-cells")?.unwrap_or(0) {
        0 => None,
        n => Some(n),
    })
}

/// Install a process-wide SIGHUP flag (the conventional "re-read your
/// config" signal — here: hot-reload the manifest).  Raw `signal(2)`
/// declaration instead of a libc dependency; the handler only stores an
/// `AtomicBool` (async-signal-safe), the serve loop polls it.
#[cfg(unix)]
fn install_sighup_flag() -> &'static std::sync::atomic::AtomicBool {
    use std::sync::atomic::{AtomicBool, Ordering};
    static FLAG: AtomicBool = AtomicBool::new(false);
    extern "C" fn on_sighup(_sig: i32) {
        FLAG.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGHUP: i32 = 1;
    unsafe {
        signal(SIGHUP, on_sighup as extern "C" fn(i32) as usize);
    }
    &FLAG
}

fn cmd_serve(args: &zqhero::cli::Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let host = args.get_or("host", "127.0.0.1").to_string();
    let port = args.get_usize("port")?.unwrap_or(7433) as u16;
    if let Some(list) = args.get("nodes") {
        return cmd_serve_front(&dir, &host, port, list, args);
    }
    let tasks: Vec<String> =
        args.get_or("tasks", "sst2").split(',').map(str::to_string).collect();
    let routes = route_names(&Manifest::load(&dir)?, args, "fp,m3")?;
    let replicas = args.get_usize("replicas")?.unwrap_or(1).max(1);
    let (queue_cap, default_deadline, governor) = overload_config(args)?;
    let (watchdog, restart) = supervision_config(args)?;
    let watch_manifest = args.get_bool("reload");
    let config = ServerConfig {
        max_batch: args.get_usize("max-batch")?.unwrap_or(16),
        max_wait: Duration::from_millis(args.get_usize("max-wait-ms")?.unwrap_or(4) as u64),
        replicas,
        queue_cap,
        default_deadline,
        governor: governor.then(|| zqhero::coordinator::GovernorConfig::for_queue(queue_cap)),
        watchdog,
        restart,
        max_resident_cells: residency_budget(args)?,
        pin_full_grid: args.get_bool("pin-full-grid"),
        ..ServerConfig::default()
    };

    ensure_route_checkpoints(&dir, &tasks, &routes, governor)?;
    let pairs: Vec<(String, String)> = tasks
        .iter()
        .flat_map(|t| routes.iter().map(move |m| (t.clone(), m.clone())))
        .collect();
    let manifest_path = dir.join("manifest.json");
    let coord = std::sync::Arc::new(Coordinator::start(dir, &pairs, config)?);
    let server = zqhero::coordinator::NetServer::start(std::sync::Arc::clone(&coord), &host, port)?;
    println!(
        "serving on {} — newline-delimited JSON (v1 mode / v2 policy frames), {replicas} engine replica(s){}",
        server.addr,
        if governor { ", governor on" } else { "" }
    );
    println!("request: {{\"task\":\"sst2\",\"mode\":\"m3\",\"ids\":[1,1510,2]}}");
    println!("     or: {{\"v\":2,\"task\":\"sst2\",\"policy\":{{\"base\":\"m3\",\"overrides\":[[\"attn_output\",\"fp\"]],\"fallback\":[\"m1\",\"fp\"]}},\"ids\":[1,1510,2]}}");
    #[cfg(unix)]
    let sighup = install_sighup_flag();
    println!(
        "Ctrl-C to stop; stats every 30s; SIGHUP{} hot-reloads the manifest",
        if watch_manifest { " or a manifest.json change" } else { "" }
    );
    let mtime_of = |p: &std::path::Path| std::fs::metadata(p).and_then(|m| m.modified()).ok();
    let mut last_mtime = mtime_of(&manifest_path);
    let mut ticks = 0u32;
    loop {
        std::thread::sleep(Duration::from_secs(1));
        let mut want_reload = false;
        #[cfg(unix)]
        if sighup.swap(false, std::sync::atomic::Ordering::SeqCst) {
            want_reload = true;
        }
        if watch_manifest {
            let now = mtime_of(&manifest_path);
            if now.is_some() && now != last_mtime {
                last_mtime = now;
                want_reload = true;
            }
        }
        if want_reload {
            // a refused reload (incompatible grid, unreadable manifest)
            // keeps the current version serving — report and carry on
            match coord.reload() {
                Ok(v) => println!("manifest hot-reloaded as version v{v}"),
                Err(e) => eprintln!("reload refused: {e:#}"),
            }
        }
        ticks += 1;
        if ticks % 30 == 0 {
            println!("\n== {} connections, {} requests (manifest v{}) ==",
                     server.connections.load(std::sync::atomic::Ordering::SeqCst),
                     server.served.load(std::sync::atomic::Ordering::SeqCst),
                     coord.current_version());
            print!("{}", coord.recorder.render());
        }
    }
}

/// `repro serve --nodes a:p,b:p` — the front-end tier (DESIGN.md §5.14):
/// net admission, depth bounding, deadlines, and the precision governor
/// on this process; batching and engines on the named engine-node
/// processes, reached over persistent pipelined v2 links.  The public
/// protocol is byte-identical to single-process `serve` — clients cannot
/// tell the tiers apart.
fn cmd_serve_front(
    dir: &std::path::Path,
    host: &str,
    port: u16,
    list: &str,
    args: &zqhero::cli::Args,
) -> Result<()> {
    use std::net::ToSocketAddrs;
    use zqhero::coordinator::{FrontEnd, FrontEndConfig};
    let (queue_cap, default_deadline, governor) = overload_config(args)?;
    let mut addrs = Vec::new();
    for s in list.split(',') {
        let s = s.trim();
        let a = s
            .to_socket_addrs()
            .with_context(|| format!("resolve engine node {s:?}"))?
            .next()
            .with_context(|| format!("engine node {s:?} resolved to no address"))?;
        addrs.push(a);
    }
    let cfg = FrontEndConfig {
        queue_cap,
        default_deadline,
        governor: governor.then(|| zqhero::coordinator::GovernorConfig::for_queue(queue_cap)),
        ..FrontEndConfig::default()
    };
    println!("front end: dialing {} engine node(s)...", addrs.len());
    let fe = std::sync::Arc::new(FrontEnd::start(dir, &addrs, cfg)?);
    let server = zqhero::coordinator::NetServer::start(std::sync::Arc::clone(&fe), host, port)?;
    println!(
        "front end serving on {} — newline-delimited JSON (v1/v2), {} engine node(s){}",
        server.addr,
        fe.nodes(),
        if governor { ", governor on" } else { "" }
    );
    println!("Ctrl-C to stop; stats every 30s");
    let mut ticks = 0u32;
    loop {
        std::thread::sleep(Duration::from_secs(1));
        ticks += 1;
        if ticks % 30 == 0 {
            println!(
                "\n== {} connections, {} requests, {}/{} engine nodes live ==",
                server.connections.load(std::sync::atomic::Ordering::SeqCst),
                server.served.load(std::sync::atomic::Ordering::SeqCst),
                fe.live_nodes(),
                fe.nodes()
            );
            print!("{}", fe.recorder().render());
        }
    }
}

/// `repro engine-node` — the engine tier (DESIGN.md §5.14): the existing
/// coordinator (engine pool, residency manager, node-local admission
/// bound) behind a length-delimited v2 link listener.  Its peers are
/// front ends, not clients: frames are pipelined and correlated by id,
/// and node-local `Busy` / expiry / replica failure cross the link as
/// the same typed wire fields the public protocol defines.
fn cmd_engine_node(args: &zqhero::cli::Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let host = args.get_or("host", "127.0.0.1").to_string();
    let port = args.get_usize("port")?.unwrap_or(7434) as u16;
    let tasks: Vec<String> =
        args.get_or("tasks", "sst2").split(',').map(str::to_string).collect();
    let routes = route_names(&Manifest::load(&dir)?, args, "fp,m3")?;
    let replicas = args.get_usize("replicas")?.unwrap_or(1).max(1);
    let queue_cap = args.get_usize("queue-cap")?.unwrap_or(1024).max(1);
    let (watchdog, restart) = supervision_config(args)?;
    let fake = match args.get_usize("fake-engine-ms")?.unwrap_or(0) {
        0 => None,
        ms => Some(Duration::from_millis(ms as u64)),
    };
    let config = ServerConfig {
        max_batch: args.get_usize("max-batch")?.unwrap_or(16),
        max_wait: Duration::from_millis(args.get_usize("max-wait-ms")?.unwrap_or(4) as u64),
        replicas,
        queue_cap,
        watchdog,
        restart,
        max_resident_cells: residency_budget(args)?,
        fake_engine: fake,
        ..ServerConfig::default()
    };
    if fake.is_none() {
        ensure_route_checkpoints(&dir, &tasks, &routes, false)?;
    }
    let pairs: Vec<(String, String)> = tasks
        .iter()
        .flat_map(|t| routes.iter().map(move |m| (t.clone(), m.clone())))
        .collect();
    let coord = std::sync::Arc::new(Coordinator::start(dir, &pairs, config)?);
    let node = zqhero::coordinator::EngineNode::start(std::sync::Arc::clone(&coord), &host, port)?;
    println!(
        "engine node serving on {} — length-delimited v2 link frames, {replicas} engine \
         replica(s){}",
        node.addr,
        if fake.is_some() { ", fake engine" } else { "" }
    );
    println!("Ctrl-C to stop; stats every 30s");
    let mut ticks = 0u32;
    loop {
        std::thread::sleep(Duration::from_secs(1));
        ticks += 1;
        if ticks % 30 == 0 {
            println!("\n== engine node (manifest v{}) ==", coord.current_version());
            print!("{}", coord.recorder.render());
        }
    }
}

fn cmd_serve_bench(args: &zqhero::cli::Args) -> Result<()> {
    let multihost_nodes = args.get_usize("nodes")?.unwrap_or(0);
    if multihost_nodes > 0 {
        // self-contained fake-engine sweep: refuse the other modes rather
        // than silently dropping their flags
        anyhow::ensure!(
            args.get_f64("overload")?.unwrap_or(0.0) == 0.0
                && !args.get_bool("chaos")
                && !args.get_bool("mixed-length")
                && !args.get_bool("residency"),
            "--nodes, --overload, --chaos, --mixed-length and --residency are separate \
             benchmarks; run one at a time"
        );
        return serve_bench_multihost(multihost_nodes, args);
    }
    let dir = artifacts_dir(args);
    let tasks: Vec<String> =
        args.get_or("tasks", "sst2").split(',').map(str::to_string).collect();
    let routes = route_names(&Manifest::load(&dir)?, args, "fp,m3")?;
    let requests = args.get_usize("requests")?.unwrap_or(256);
    let concurrency = args.get_usize("concurrency")?.unwrap_or(32);
    let replicas = args.get_usize("replicas")?.unwrap_or(1).max(1);
    let (queue_cap, default_deadline, governor) = overload_config(args)?;
    let (watchdog, restart) = supervision_config(args)?;
    let overload = args.get_f64("overload")?.unwrap_or(0.0);
    let config = ServerConfig {
        max_batch: args.get_usize("max-batch")?.unwrap_or(16),
        max_wait: Duration::from_millis(args.get_usize("max-wait-ms")?.unwrap_or(4) as u64),
        replicas,
        queue_cap,
        default_deadline,
        governor: governor.then(|| zqhero::coordinator::GovernorConfig::for_queue(queue_cap)),
        watchdog,
        restart,
        max_resident_cells: residency_budget(args)?,
        ..ServerConfig::default()
    };

    ensure_route_checkpoints(&dir, &tasks, &routes, governor)?;

    let pairs: Vec<(String, String)> = tasks
        .iter()
        .flat_map(|t| routes.iter().map(move |m| (t.clone(), m.clone())))
        .collect();

    // pull eval rows as the request payloads
    let man = Manifest::load(&dir)?;
    let payloads = load_payloads(&man, &tasks, requests)?;

    if args.get_bool("residency") {
        anyhow::ensure!(
            overload == 0.0 && !args.get_bool("chaos") && !args.get_bool("mixed-length"),
            "--residency, --mixed-length, --overload and --chaos are separate benchmarks; \
             run one at a time"
        );
        return serve_bench_residency(
            &dir, &man, &tasks, &routes, &payloads, requests, concurrency, config,
        );
    }

    if args.get_bool("mixed-length") {
        // refuse rather than silently drop the other mode's flag: a
        // BENCH_seq_buckets_smoke.json from a closed loop must not be
        // misread as an overload measurement
        anyhow::ensure!(
            overload == 0.0 && !args.get_bool("chaos"),
            "--mixed-length, --overload and --chaos are separate benchmarks; run one at a time"
        );
        return serve_bench_seq_buckets(
            &dir, &man, &tasks, &routes, &payloads, requests, concurrency, config,
        );
    }

    if args.get_bool("chaos") {
        anyhow::ensure!(
            overload == 0.0,
            "--chaos and --overload are separate benchmarks; run one at a time"
        );
        return serve_bench_chaos(&dir, &tasks, &routes, &payloads, requests, concurrency, config);
    }

    println!("starting coordinator ({} task x policy routes)...", pairs.len());
    let coord = Coordinator::start(dir.clone(), &pairs, config)?;

    if overload > 0.0 {
        return serve_bench_overload(
            &coord, &man, &tasks, &routes, &payloads, requests, overload, default_deadline,
            governor,
        );
    }

    println!(
        "running closed-loop load: {requests} requests per route, {concurrency} in flight \
         per route (routes driven concurrently)"
    );
    let t0 = Instant::now();
    // one closed loop per (task, route), all concurrent: sequential route
    // loops would keep a single batch group in flight, and per-group
    // pinning would park every batch on one replica — concurrent groups
    // are what the load-aware dispatcher spreads
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for (ti, t) in tasks.iter().enumerate() {
            for m in &routes {
                let rows = &payloads[ti];
                let coord = &coord;
                handles.push(
                    s.spawn(move || drive_closed_loop(coord, t, m, rows, requests, concurrency)),
                );
            }
        }
        for h in handles {
            h.join().map_err(|_| anyhow::anyhow!("load thread panicked"))??;
        }
        Ok(())
    })?;
    let wall = t0.elapsed().as_secs_f64();
    println!("\n== serving metrics ({wall:.1}s wall, {replicas} engine replica(s)) ==");
    print!("{}", coord.recorder.render());

    // machine-readable smoke point for multi-replica runs: per-replica
    // batch counts prove the load-aware dispatcher spread the work (the
    // full 1-vs-N sweep lives in benches/e2e_serving.rs)
    if replicas > 1 {
        use zqhero::json::{self, Value};
        let reps = coord.recorder.replica_snapshot();
        let total_batches: u64 = reps.iter().map(|r| r.batches).sum();
        let per_replica: Vec<Value> = reps
            .iter()
            .map(|r| {
                json::obj(vec![
                    ("batches", json::num(r.batches as f64)),
                    ("rows", json::num(r.rows as f64)),
                ])
            })
            .collect();
        let report = json::obj(vec![
            ("bench", json::s("replica_scaling_smoke")),
            ("replicas", json::num(replicas as f64)),
            ("requests", json::num(requests as f64)),
            ("wall_s", json::num(wall)),
            ("total_batches", json::num(total_batches as f64)),
            ("per_replica", Value::Array(per_replica)),
        ]);
        // distinct filename: the canonical 1-vs-N sweep trajectory
        // (benches/e2e_serving.rs) owns BENCH_replica_scaling.json and
        // must not be clobbered by a smoke run with a different schema
        match std::fs::write("BENCH_replica_scaling_smoke.json", json::to_string_pretty(&report)) {
            Ok(()) => println!("\nwrote BENCH_replica_scaling_smoke.json"),
            Err(e) => eprintln!("could not write BENCH_replica_scaling_smoke.json: {e}"),
        }
    }
    Ok(())
}

/// Dev-split rows per task, the request payloads of every serve-bench
/// variant.  Rows come back at the container length (the model max, PAD
/// tail included) — the mixed-length smoke trims them to real lengths.
fn load_payloads(
    man: &Manifest,
    tasks: &[String],
    requests: usize,
) -> Result<Vec<Vec<(Vec<i32>, Vec<i32>)>>> {
    let mut payloads = Vec::new();
    for t in tasks {
        let task = man.task(t)?;
        let split = zqhero::data::Split::load(man, task, "dev")?;
        let rows: Vec<(Vec<i32>, Vec<i32>)> = (0..split.len().min(requests))
            .map(|i| {
                let (a, b) = split.row(i);
                (a.to_vec(), b.to_vec())
            })
            .collect();
        payloads.push(rows);
    }
    Ok(payloads)
}

/// One closed loop over a (task, route) through the shared
/// `zqhero::bench::closed_loop` driver — the CLI smoke and the e2e bench
/// measure identical serving behavior.
fn drive_closed_loop(
    coord: &Coordinator,
    task: &str,
    route: &str,
    rows: &[(Vec<i32>, Vec<i32>)],
    requests: usize,
    concurrency: usize,
) -> Result<()> {
    let policy = zqhero::coordinator::PolicyRef::Named(route.to_string());
    zqhero::bench::closed_loop(coord, task, &policy, rows, requests, concurrency).map(|_| ())
}

/// Length-aware serving smoke (`serve-bench --mixed-length`): drive the
/// same dev rows through two fresh coordinators — once padded to the
/// model max client-side (the pre-grid single-seq baseline) and once at
/// their real lengths (bucketed) — and report each run's padded-token
/// volume, padding efficiency, and wall time.  Writes
/// BENCH_seq_buckets_smoke.json; the full mixed-length sweep with the
/// >=2x padded-token reduction assertion lives in benches/e2e_serving.rs
/// (BENCH_seq_buckets.json).
#[allow(clippy::too_many_arguments)]
fn serve_bench_seq_buckets(
    dir: &std::path::Path,
    man: &Manifest,
    tasks: &[String],
    routes: &[String],
    payloads: &[Vec<(Vec<i32>, Vec<i32>)>],
    requests: usize,
    concurrency: usize,
    config: ServerConfig,
) -> Result<()> {
    use zqhero::json::{self, Value};
    let pairs: Vec<(String, String)> = tasks
        .iter()
        .flat_map(|t| routes.iter().map(move |m| (t.clone(), m.clone())))
        .collect();
    println!(
        "mixed-length smoke: {requests} requests per route, seq buckets {:?}",
        man.seq_buckets
    );
    if man.num_seq_buckets() == 1 {
        println!(
            "note: single-seq manifest (format_version 2 artifacts) — both variants will \
             pay identical padded-token volume"
        );
    }

    let mut variants: Vec<(String, Value)> = Vec::new();
    let mut padded_volume: Vec<(String, u64)> = Vec::new();
    for (label, trim) in [("padded", false), ("bucketed", true)] {
        let rows_by_task: Vec<Vec<(Vec<i32>, Vec<i32>)>> = payloads
            .iter()
            .map(|rows| {
                rows.iter()
                    .map(|(ids, tys)| {
                        if trim {
                            zqhero::data::trim_pad_tail(ids, tys)
                        } else {
                            (ids.clone(), tys.clone())
                        }
                    })
                    .collect()
            })
            .collect();
        // fresh coordinator per variant so the recorders are comparable
        let coord = Coordinator::start(dir.to_path_buf(), &pairs, config.clone())?;
        let t0 = Instant::now();
        std::thread::scope(|s| -> Result<()> {
            let mut handles = Vec::new();
            for (ti, t) in tasks.iter().enumerate() {
                for m in routes {
                    let rows = &rows_by_task[ti];
                    let coord = &coord;
                    handles.push(s.spawn(move || {
                        drive_closed_loop(coord, t, m, rows, requests, concurrency)
                    }));
                }
            }
            for h in handles {
                h.join().map_err(|_| anyhow::anyhow!("load thread panicked"))??;
            }
            Ok(())
        })?;
        let wall = t0.elapsed().as_secs_f64();
        let snap = coord.recorder.snapshot();
        let (real, padded) = zqhero::bench::padding_totals(&snap);
        let per_policy: Vec<(String, Value)> = snap
            .iter()
            .map(|(name, s)| {
                (
                    name.clone(),
                    json::obj(vec![
                        ("padded_tokens", json::num(s.padded_tokens as f64)),
                        ("pad_efficiency", json::num(s.padding_efficiency())),
                        ("p50_ms", json::num(s.latency.percentile_us(0.50) as f64 / 1e3)),
                        ("p99_ms", json::num(s.latency.percentile_us(0.99) as f64 / 1e3)),
                    ]),
                )
            })
            .collect();
        println!(
            "  {label:8} {padded:>10} padded tokens, {real:>10} real ({:.0}% efficient), \
             {wall:.1}s wall",
            100.0 * real as f64 / padded.max(1) as f64
        );
        print!("{}", coord.recorder.render());
        variants.push((
            label.to_string(),
            json::obj(vec![
                ("padded_tokens", json::num(padded as f64)),
                ("real_tokens", json::num(real as f64)),
                ("pad_efficiency", json::num(real as f64 / padded.max(1) as f64)),
                ("wall_s", json::num(wall)),
                ("policies", Value::Object(per_policy)),
            ]),
        ));
        padded_volume.push((label.to_string(), padded));
    }

    let base = padded_volume.iter().find(|(l, _)| l == "padded").map(|(_, v)| *v).unwrap_or(0);
    let bucketed =
        padded_volume.iter().find(|(l, _)| l == "bucketed").map(|(_, v)| *v).unwrap_or(0);
    let reduction = base as f64 / bucketed.max(1) as f64;
    println!("\npadded-token reduction (padded / bucketed): {reduction:.2}x");
    let report = json::obj(vec![
        ("bench", json::s("seq_buckets_smoke")),
        ("tasks", Value::Array(tasks.iter().map(|t| json::s(t)).collect())),
        ("routes", Value::Array(routes.iter().map(|r| json::s(r)).collect())),
        ("requests_per_route", json::num(requests as f64)),
        (
            "seq_buckets",
            Value::Array(man.seq_buckets.iter().map(|s| json::num(*s as f64)).collect()),
        ),
        ("variants", Value::Object(variants)),
        ("padded_token_reduction", json::num(reduction)),
    ]);
    match std::fs::write("BENCH_seq_buckets_smoke.json", json::to_string_pretty(&report)) {
        Ok(()) => println!("wrote BENCH_seq_buckets_smoke.json"),
        Err(e) => eprintln!("could not write BENCH_seq_buckets_smoke.json: {e}"),
    }
    Ok(())
}

/// Executable-residency smoke (`serve-bench --residency`, DESIGN.md
/// §5.13): run the identical closed loop twice — first on the pin-set
/// startup (demand cells compile on first miss under the LRU budget),
/// then with `pin_full_grid` (the pre-residency eager `(mode x seq x
/// batch)` preload) — and report startup time and load counts, the
/// hit/miss/eviction ledger, VmRSS, and the latency split between
/// requests that found their cell resident and those that paid a cold
/// compile (`Timing::load_wait_us > 0`).  The pinned phase asserts the
/// acceptance invariant: startup loads exactly the pin set (each
/// requested route's exec mode x every seq bucket at the serving batch
/// bucket), never the cross-product.  Writes BENCH_residency.json.
#[allow(clippy::too_many_arguments)]
fn serve_bench_residency(
    dir: &std::path::Path,
    man: &Manifest,
    tasks: &[String],
    routes: &[String],
    payloads: &[Vec<(Vec<i32>, Vec<i32>)>],
    requests: usize,
    concurrency: usize,
    config: ServerConfig,
) -> Result<()> {
    use zqhero::json::{self, Value};
    anyhow::ensure!(
        config.governor.is_none(),
        "--residency measures cold/warm cell behavior on fixed routes; run it without --governor"
    );
    let pairs: Vec<(String, String)> = tasks
        .iter()
        .flat_map(|t| routes.iter().map(move |m| (t.clone(), m.clone())))
        .collect();
    // mirror the coordinator's pin-set derivation so the ledger can be
    // checked from the outside: requested routes' exec modes (deduped)
    // x every seq bucket, at one batch bucket (the serving max-batch)
    let mut exec_modes: Vec<zqhero::model::manifest::ModeId> = Vec::new();
    for r in routes {
        let m = man.policy(r)?.exec_mode;
        if !exec_modes.contains(&m) {
            exec_modes.push(m);
        }
    }
    let pin_cells = exec_modes.len() * man.num_seq_buckets();
    let grid_cells = pin_cells * man.buckets.len();
    println!(
        "residency smoke: pin set {pin_cells} cells vs full grid {grid_cells} cells \
         ({} modes x {} seq buckets x {} batch buckets), budget {:?}",
        exec_modes.len(),
        man.num_seq_buckets(),
        man.buckets.len(),
        config.max_resident_cells,
    );

    fn pctl_ms(sorted: &[u64], p: f64) -> f64 {
        if sorted.is_empty() {
            return 0.0;
        }
        let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
        sorted[idx] as f64 / 1e3
    }

    let mut phases: Vec<(String, Value)> = Vec::new();
    for (label, full_grid, expected_startup) in
        [("pinned", false, pin_cells), ("eager", true, grid_cells)]
    {
        let mut cfg = config.clone();
        cfg.pin_full_grid = full_grid;
        let t_start = Instant::now();
        let coord = Coordinator::start(dir.to_path_buf(), &pairs, cfg)?;
        let startup_s = t_start.elapsed().as_secs_f64();
        // ledger the startup loads before any traffic: the acceptance
        // witness that startup loaded exactly the pin set (or, in the
        // eager phase, the whole grid)
        let startup = coord.recorder.residency_snapshot();
        for (i, r) in startup.iter().enumerate() {
            anyhow::ensure!(
                r.loads as usize == expected_startup,
                "{label}: replica {i} loaded {} cells at startup, expected {expected_startup}",
                r.loads
            );
            anyhow::ensure!(
                r.loads == r.pinned_loads && r.misses == 0,
                "{label}: startup loads must all be pins ({} loads, {} pinned, {} misses)",
                r.loads,
                r.pinned_loads,
                r.misses
            );
        }
        let startup_loads: u64 = startup.iter().map(|r| r.loads).sum();

        let t0 = Instant::now();
        let mut samples: Vec<(u64, u64)> = Vec::new(); // (total_us, load_wait_us)
        std::thread::scope(|s| -> Result<()> {
            let mut handles = Vec::new();
            for (ti, t) in tasks.iter().enumerate() {
                for m in routes {
                    let rows = &payloads[ti];
                    let coord = &coord;
                    handles.push(
                        s.spawn(move || residency_loop(coord, t, m, rows, requests, concurrency)),
                    );
                }
            }
            for h in handles {
                samples.extend(h.join().map_err(|_| anyhow::anyhow!("load thread panicked"))??);
            }
            Ok(())
        })?;
        let wall = t0.elapsed().as_secs_f64();

        let res = coord.recorder.residency_snapshot();
        let (hits, misses, evictions): (u64, u64, u64) = res
            .iter()
            .fold((0, 0, 0), |a, r| (a.0 + r.hits, a.1 + r.misses, a.2 + r.evictions));
        if let Some(cap) = config.max_resident_cells {
            for (i, r) in res.iter().enumerate() {
                anyhow::ensure!(
                    r.resident <= cap,
                    "{label}: replica {i} holds {} resident cells over the {cap} budget",
                    r.resident
                );
            }
        }
        let resident: u64 = res.iter().map(|r| r.resident as u64).sum();
        let mut all: Vec<u64> = samples.iter().map(|(t, _)| *t).collect();
        let mut warm: Vec<u64> =
            samples.iter().filter(|(_, w)| *w == 0).map(|(t, _)| *t).collect();
        let mut cold: Vec<u64> = samples.iter().filter(|(_, w)| *w > 0).map(|(t, _)| *t).collect();
        all.sort_unstable();
        warm.sort_unstable();
        cold.sort_unstable();
        let rss_kb = vm_rss_kb().unwrap_or(0);
        println!(
            "  {label:7} startup {startup_s:.2}s ({startup_loads} cell loads), {hits} hits / \
             {misses} misses / {evictions} evictions, {resident} resident, p99 {:.1}ms (warm \
             {:.1}ms, cold-cell {:.1}ms over {} reqs), {wall:.1}s wall, VmRSS {rss_kb} kB",
            pctl_ms(&all, 0.99),
            pctl_ms(&warm, 0.99),
            pctl_ms(&cold, 0.99),
            cold.len(),
        );
        print!("{}", coord.recorder.render());
        phases.push((
            label.to_string(),
            json::obj(vec![
                ("startup_s", json::num(startup_s)),
                ("startup_cell_loads", json::num(startup_loads as f64)),
                ("expected_startup_cells", json::num(expected_startup as f64)),
                ("hits", json::num(hits as f64)),
                ("misses", json::num(misses as f64)),
                ("evictions", json::num(evictions as f64)),
                ("resident_cells", json::num(resident as f64)),
                ("p50_ms", json::num(pctl_ms(&all, 0.50))),
                ("p99_ms", json::num(pctl_ms(&all, 0.99))),
                ("warm_p99_ms", json::num(pctl_ms(&warm, 0.99))),
                ("cold_p99_ms", json::num(pctl_ms(&cold, 0.99))),
                ("cold_requests", json::num(cold.len() as f64)),
                ("wall_s", json::num(wall)),
                ("vm_rss_kb", json::num(rss_kb as f64)),
            ]),
        ));
    }

    let report = json::obj(vec![
        ("bench", json::s("residency_smoke")),
        ("tasks", Value::Array(tasks.iter().map(|t| json::s(t)).collect())),
        ("routes", Value::Array(routes.iter().map(|r| json::s(r)).collect())),
        ("requests_per_route", json::num(requests as f64)),
        (
            "max_resident_cells",
            json::num(config.max_resident_cells.map(|c| c as f64).unwrap_or(0.0)),
        ),
        ("pin_cells", json::num(pin_cells as f64)),
        ("grid_cells", json::num(grid_cells as f64)),
        ("phases", Value::Object(phases)),
    ]);
    match std::fs::write("BENCH_residency.json", json::to_string_pretty(&report)) {
        Ok(()) => println!("\nwrote BENCH_residency.json"),
        Err(e) => eprintln!("could not write BENCH_residency.json: {e}"),
    }
    Ok(())
}

/// Closed loop that returns each completed request's
/// `(total_us, load_wait_us)` — the residency smoke's warm/cold split
/// primitive.  Any terminal outcome other than completion is a bug.
fn residency_loop(
    coord: &Coordinator,
    task: &str,
    route: &str,
    rows: &[(Vec<i32>, Vec<i32>)],
    requests: usize,
    concurrency: usize,
) -> Result<Vec<(u64, u64)>> {
    let mut inflight = std::collections::VecDeque::new();
    let mut out = Vec::with_capacity(requests);
    let mut submitted = 0usize;
    while out.len() < requests {
        while submitted < requests && inflight.len() < concurrency.max(1) {
            let (ids, tys) = rows[submitted % rows.len()].clone();
            // explicit long deadline: a cold-cell compile must show up as
            // load_wait_us, never as a spurious expiry
            let spec = zqhero::coordinator::RequestSpec::task(task)
                .policy(route)
                .ids(ids)
                .type_ids(tys)
                .deadline(Duration::from_secs(600));
            match coord.submit(spec) {
                Ok(rx) => {
                    inflight.push_back(rx);
                    submitted += 1;
                }
                Err(e) if e.is_busy() => break,
                Err(e) => anyhow::bail!("residency submit failed: {e}"),
            }
        }
        match inflight.pop_front() {
            Some(rx) => {
                let resp = rx.recv().context("residency response channel closed")?;
                anyhow::ensure!(
                    resp.error.is_none() && !resp.expired && !resp.failed,
                    "residency smoke request did not complete: {:?}",
                    resp.error
                );
                out.push((resp.timing.total_us, resp.timing.load_wait_us));
            }
            None => std::thread::sleep(Duration::from_micros(200)),
        }
    }
    Ok(out)
}

/// Resident-set size from `/proc/self/status`, in kB (`None` off-Linux).
fn vm_rss_kb() -> Option<u64> {
    let s = std::fs::read_to_string("/proc/self/status").ok()?;
    s.lines().find(|l| l.starts_with("VmRSS:"))?.split_whitespace().nth(1)?.parse().ok()
}

/// Open-loop overload smoke (`serve-bench --overload X [--governor]`):
/// measure capacity with a short closed loop, then fire arrivals at X
/// times that rate regardless of completions, with per-request
/// deadlines, and report the shed/expired/completed ledger (it must
/// reconcile exactly: admitted = completed + shed + expired).  The full
/// governor-on/off sweep lives in `benches/e2e_serving.rs`
/// (BENCH_overload.json); this is the CLI/CI surface.
#[allow(clippy::too_many_arguments)]
fn serve_bench_overload(
    coord: &Coordinator,
    man: &Manifest,
    tasks: &[String],
    routes: &[String],
    payloads: &[Vec<(Vec<i32>, Vec<i32>)>],
    requests: usize,
    overload: f64,
    default_deadline: Option<Duration>,
    governor: bool,
) -> Result<()> {
    // prefer a governable route (non-empty degradation chain) so the
    // governor has something to do; else the first route
    let task = tasks.first().context("no tasks")?.clone();
    let rows = &payloads[0];
    let route = routes
        .iter()
        .find(|r| {
            governor
                && man
                    .policy_id(r.as_str())
                    .map(|p| !man.downgrade_chain(p).is_empty())
                    .unwrap_or(false)
        })
        .unwrap_or_else(|| &routes[0])
        .clone();
    let deadline = default_deadline.unwrap_or(Duration::from_millis(250));

    println!("measuring capacity on ({task},{route}) with a short closed loop...");
    let capacity_rps = closed_loop_capacity(coord, &task, &route, rows, requests.max(64) / 2)?;
    let rate = capacity_rps * overload;
    println!(
        "capacity ~{capacity_rps:.1} req/s; open-loop burst: {requests} arrivals at \
         {rate:.1} req/s ({overload}x), deadline {}ms, governor {}",
        deadline.as_millis(),
        if governor { "on" } else { "off" },
    );
    let r = zqhero::bench::open_loop_burst(coord, &task, &route, rows, requests, rate, deadline)?;
    anyhow::ensure!(
        r.reconciles(),
        "overload ledger must reconcile: {} != {} + {} + {}",
        r.admitted,
        r.completed,
        r.shed,
        r.expired
    );
    println!(
        "\nadmitted {} = completed {} + shed {} + expired {}  (p50 {:.1}ms, p99 {:.1}ms, \
         goodput {:.1} req/s)",
        r.admitted,
        r.completed,
        r.shed,
        r.expired,
        r.p50_ms,
        r.p99_ms,
        r.goodput_rps(),
    );
    print!("{}", coord.recorder.render());

    use zqhero::json;
    let report = json::obj(vec![
        ("bench", json::s("overload_smoke")),
        ("task", json::s(&task)),
        ("route", json::s(&route)),
        ("governor", zqhero::json::Value::Bool(governor)),
        ("overload_x", json::num(overload)),
        ("capacity_rps", json::num(capacity_rps)),
        ("deadline_ms", json::num(deadline.as_millis() as f64)),
        ("admitted", json::num(r.admitted as f64)),
        ("completed", json::num(r.completed as f64)),
        ("shed", json::num(r.shed as f64)),
        ("expired", json::num(r.expired as f64)),
        ("p50_ms", json::num(r.p50_ms)),
        ("p99_ms", json::num(r.p99_ms)),
        ("goodput_rps", json::num(r.goodput_rps())),
    ]);
    match std::fs::write("BENCH_overload_smoke.json", json::to_string_pretty(&report)) {
        Ok(()) => println!("\nwrote BENCH_overload_smoke.json"),
        Err(e) => eprintln!("could not write BENCH_overload_smoke.json: {e}"),
    }
    Ok(())
}

/// Short single-threaded closed loop; returns completed-request
/// throughput (the capacity estimate the overload burst multiplies).
fn closed_loop_capacity(
    coord: &Coordinator,
    task: &str,
    route: &str,
    rows: &[(Vec<i32>, Vec<i32>)],
    requests: usize,
) -> Result<f64> {
    let t0 = Instant::now();
    let mut inflight = std::collections::VecDeque::new();
    let (mut submitted, mut done) = (0usize, 0usize);
    while done < requests {
        while submitted < requests && inflight.len() < 16 {
            let (ids, tys) = rows[submitted % rows.len()].clone();
            // explicit long deadline: calibration must not expire under
            // a tight --default-deadline-ms meant for the burst
            let spec = zqhero::coordinator::RequestSpec::task(task)
                .policy(route)
                .ids(ids)
                .type_ids(tys)
                .deadline(Duration::from_secs(600));
            match coord.submit(spec) {
                Ok(rx) => {
                    inflight.push_back(rx);
                    submitted += 1;
                }
                Err(e) if e.is_busy() => break,
                Err(e) => anyhow::bail!("calibration submit failed: {e}"),
            }
        }
        match inflight.pop_front() {
            Some(rx) => {
                let resp = rx.recv().context("calibration response channel closed")?;
                anyhow::ensure!(
                    resp.error.is_none(),
                    "calibration request failed: {:?}",
                    resp.error
                );
                done += 1;
            }
            None => std::thread::sleep(Duration::from_micros(200)),
        }
    }
    Ok(requests as f64 / t0.elapsed().as_secs_f64().max(1e-9))
}

/// Replica-supervision chaos smoke (`serve-bench --chaos`, DESIGN.md
/// §5.10): measure fault-free goodput, then rerun the identical load
/// with a fault plan that panics one replica mid-run.  The supervisor
/// must sweep the orphaned batches into typed failures (every client
/// gets a terminal reply; the ledger reconciles exactly), restart the
/// replica, and a post-recovery loop must reach >= 90% of the baseline
/// goodput.  Writes BENCH_chaos_smoke.json; the exhaustive fault matrix
/// lives in tests/chaos_integration.rs on the fake engine.
fn serve_bench_chaos(
    dir: &std::path::Path,
    tasks: &[String],
    routes: &[String],
    payloads: &[Vec<(Vec<i32>, Vec<i32>)>],
    requests: usize,
    concurrency: usize,
    mut config: ServerConfig,
) -> Result<()> {
    use zqhero::json;
    // failover needs somewhere to go: at least two replicas, and enough
    // requests that the planned fault is guaranteed to trip mid-run
    config.replicas = config.replicas.max(2);
    let replicas = config.replicas;
    let requests = requests.max(4 * config.max_batch.max(1));
    let task = tasks.first().context("no tasks")?.clone();
    let route = routes.first().context("no routes")?.clone();
    let rows = &payloads[0];
    let pairs = vec![(task.clone(), route.clone())];

    // phase 1: fault-free baseline goodput on an identical coordinator
    println!("chaos smoke: baseline closed loop ({requests} requests, {replicas} replicas)...");
    let baseline_rps = {
        let coord = Coordinator::start(dir.to_path_buf(), &pairs, config.clone())?;
        let (completed, failed, wall) =
            chaos_loop(&coord, &task, &route, rows, requests, concurrency)?;
        anyhow::ensure!(failed == 0, "baseline run saw {failed} replica failures");
        completed as f64 / wall.max(1e-9)
    };
    println!("baseline goodput ~{baseline_rps:.1} req/s");

    // phase 2: identical load, but replica 0 is planned to panic on its
    // second batch — per-group pinning lands the first batches there, so
    // the fault is reached deterministically
    config.fault_plan = FaultPlan::default().with(FaultSpec::on(0, FaultKind::PanicAt { batch: 1 }));
    let coord = Coordinator::start(dir.to_path_buf(), &pairs, config)?;
    println!("fault window: replica 0 panics at its batch 1...");
    let (completed, failed, fault_wall) =
        chaos_loop(&coord, &task, &route, rows, requests, concurrency)?;
    anyhow::ensure!(
        completed + failed == requests,
        "chaos ledger lost replies: {completed} completed + {failed} failed != {requests}"
    );
    anyhow::ensure!(failed > 0, "the planned fault never fired — not a chaos run");
    println!("fault window: {completed} completed + {failed} failed (typed) in {fault_wall:.1}s");

    // recorder side must agree exactly with the client-side ledger
    let snap = coord.recorder.snapshot();
    let s = &snap[route.as_str()];
    anyhow::ensure!(
        s.completed as usize == completed && s.failed as usize == failed && s.errors == 0,
        "recorder disagrees with the client ledger: completed {} vs {completed}, failed {} vs \
         {failed}, errors {}",
        s.completed,
        s.failed,
        s.errors
    );

    // phase 3: the supervisor must restart replica 0 and return the pool
    // to full strength; goodput must then recover
    let t0 = Instant::now();
    while coord.engine().live_replicas() < replicas {
        anyhow::ensure!(
            t0.elapsed() < Duration::from_secs(30),
            "replica 0 never came back: {}/{replicas} live after 30s",
            coord.engine().live_replicas()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    let restarts = coord.engine().replica_restarts(0);
    anyhow::ensure!(restarts >= 1, "pool is full strength but replica 0 ledgered no restart");
    let (rec_completed, rec_failed, rec_wall) =
        chaos_loop(&coord, &task, &route, rows, requests, concurrency)?;
    anyhow::ensure!(rec_failed == 0, "post-recovery run saw {rec_failed} replica failures");
    let recovered_rps = rec_completed as f64 / rec_wall.max(1e-9);
    let ratio = recovered_rps / baseline_rps.max(1e-9);
    println!("recovered goodput ~{recovered_rps:.1} req/s ({:.0}% of baseline)", 100.0 * ratio);
    anyhow::ensure!(
        ratio >= 0.9,
        "goodput did not recover: {recovered_rps:.1} req/s vs baseline {baseline_rps:.1} \
         (need >= 90%)"
    );
    anyhow::ensure!(coord.queue_depth() == 0, "backlog slots leaked after drain");
    print!("{}", coord.recorder.render());

    let report = json::obj(vec![
        ("bench", json::s("chaos_smoke")),
        ("task", json::s(&task)),
        ("route", json::s(&route)),
        ("replicas", json::num(replicas as f64)),
        ("requests_per_phase", json::num(requests as f64)),
        ("baseline_rps", json::num(baseline_rps)),
        ("fault_completed", json::num(completed as f64)),
        ("fault_failed", json::num(failed as f64)),
        ("fault_wall_s", json::num(fault_wall)),
        ("replica0_restarts", json::num(restarts as f64)),
        ("recovered_rps", json::num(recovered_rps)),
        ("recovery_ratio", json::num(ratio)),
    ]);
    match std::fs::write("BENCH_chaos_smoke.json", json::to_string_pretty(&report)) {
        Ok(()) => println!("\nwrote BENCH_chaos_smoke.json"),
        Err(e) => eprintln!("could not write BENCH_chaos_smoke.json: {e}"),
    }
    Ok(())
}

/// Closed loop that tolerates (and counts) typed replica-failure
/// replies — the chaos smoke's measurement primitive.  Returns
/// `(completed, failed, wall_s)`; any other terminal outcome is a bug.
fn chaos_loop(
    coord: &Coordinator,
    task: &str,
    route: &str,
    rows: &[(Vec<i32>, Vec<i32>)],
    requests: usize,
    concurrency: usize,
) -> Result<(usize, usize, f64)> {
    let t0 = Instant::now();
    let mut inflight = std::collections::VecDeque::new();
    let (mut submitted, mut completed, mut failed) = (0usize, 0usize, 0usize);
    while completed + failed < requests {
        while submitted < requests && inflight.len() < concurrency.max(1) {
            let (ids, tys) = rows[submitted % rows.len()].clone();
            // explicit long deadline: the fault window must produce typed
            // failures, never expiries racing the supervisor's sweep
            let spec = zqhero::coordinator::RequestSpec::task(task)
                .policy(route)
                .ids(ids)
                .type_ids(tys)
                .deadline(Duration::from_secs(600));
            match coord.submit(spec) {
                Ok(rx) => {
                    inflight.push_back(rx);
                    submitted += 1;
                }
                Err(e) if e.is_busy() => break,
                Err(e) => anyhow::bail!("chaos submit failed: {e}"),
            }
        }
        match inflight.pop_front() {
            Some(rx) => {
                let resp = rx.recv().context("chaos response channel closed")?;
                if resp.failed {
                    failed += 1;
                } else {
                    anyhow::ensure!(
                        resp.error.is_none(),
                        "unexpected request error: {:?}",
                        resp.error
                    );
                    anyhow::ensure!(!resp.expired, "request expired under a 600s deadline");
                    completed += 1;
                }
            }
            None => std::thread::sleep(Duration::from_micros(200)),
        }
    }
    Ok((completed, failed, t0.elapsed().as_secs_f64()))
}


/// Fake-engine manifest for the multihost sweep: two tasks x two modes
/// = four (task, policy) groups, so `NodeDispatch` has concurrent groups
/// to spread (one group pins to one node while it has requests in
/// flight — a single group can never exercise more than one node).
/// Checkpoints are declared but never opened under `fake_engine`.
const MULTIHOST_MANIFEST: &str = r#"{
  "model": {"vocab_size": 64, "hidden": 8, "layers": 1, "heads": 2, "ffn": 16,
            "max_seq": 8, "type_vocab": 2, "num_labels": 3, "ln_eps": 0.00001},
  "seq": 8,
  "buckets": [1, 2, 4],
  "modes": {
    "fp": {
      "switches": {"embedding": false, "qkv": false, "attn": false,
                   "attn_output": false, "fc1": false, "fc2": false},
      "artifacts": {},
      "params": []
    },
    "m3": {
      "switches": {"embedding": true, "qkv": true, "attn": true,
                   "attn_output": true, "fc1": true, "fc2": true},
      "artifacts": {},
      "params": []
    }
  },
  "calib": {"artifact": "calib.bin", "batch": 1, "params": [], "stats": []},
  "tasks": {
    "mh-a": {"splits": {}, "metrics": [], "classes": 3, "checkpoint": "ckpt-{mode}.bin"},
    "mh-b": {"splits": {}, "metrics": [], "classes": 3, "checkpoint": "ckpt-{mode}.bin"}
  }
}"#;

/// Multi-host scale-out sweep (`serve-bench --nodes N`, DESIGN.md
/// §5.14): for each tier size 1..=N, start that many fake-engine node
/// processes-worth of coordinators behind `EngineNode` listeners and one
/// `FrontEnd` over real TCP links, drive an open-loop burst at 2x the
/// measured single-node capacity *per node*, and report goodput/p99 per
/// tier size.  Self-contained (fake engine, temp-dir manifest) so CI
/// runs it unconditionally.  Gates: every ledger reconciles exactly on
/// both tiers, and 2 nodes must reach >= 1.7x the 1-node goodput.
fn serve_bench_multihost(max_nodes: usize, args: &zqhero::cli::Args) -> Result<()> {
    use std::sync::Arc;
    use zqhero::coordinator::{EngineNode, FrontEnd, FrontEndConfig};
    use zqhero::json::{self, Value};

    let requests = args.get_usize("requests")?.unwrap_or(256);
    let concurrency = args.get_usize("concurrency")?.unwrap_or(32);

    let dir = std::env::temp_dir().join(format!("zqhero-multihost-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join("manifest.json"), MULTIHOST_MANIFEST)?;

    let tasks = ["mh-a", "mh-b"];
    let routes = ["fp", "m3"];
    let groups: Vec<(String, String)> = tasks
        .iter()
        .flat_map(|t| routes.iter().map(move |r| (t.to_string(), r.to_string())))
        .collect();
    let pairs = groups.clone();
    // payload lengths sweep the seq range so both seq classes appear
    let rows: Vec<(Vec<i32>, Vec<i32>)> = (0..16)
        .map(|i| {
            let len = 1 + i % 8;
            ((0..len as i32).collect(), vec![0; len])
        })
        .collect();
    let fake_latency = Duration::from_millis(3);
    let node_config = ServerConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        queue_cap: 256,
        fake_engine: Some(fake_latency),
        ..ServerConfig::default()
    };
    let fe_config = FrontEndConfig { queue_cap: 512, ..FrontEndConfig::default() };

    let start_tier = |n: usize| -> Result<(Vec<(Arc<Coordinator>, EngineNode)>, FrontEnd)> {
        let mut nodes = Vec::with_capacity(n);
        let mut addrs = Vec::with_capacity(n);
        for _ in 0..n {
            let coord = Arc::new(Coordinator::start(dir.clone(), &pairs, node_config.clone())?);
            let node = EngineNode::start(Arc::clone(&coord), "127.0.0.1", 0)?;
            addrs.push(node.addr);
            nodes.push((coord, node));
        }
        let fe = FrontEnd::start(&dir, &addrs, fe_config.clone())?;
        Ok((nodes, fe))
    };

    // capacity of one node measured through the two-tier path itself
    // (closed loop, all groups concurrent) — the burst rates scale off it
    println!("multihost sweep: measuring 1-node capacity through the front end...");
    let per_group = (requests / groups.len()).max(16);
    let capacity_rps = {
        let (nodes, fe) = start_tier(1)?;
        let t0 = Instant::now();
        let fe_ref = &fe;
        std::thread::scope(|s| -> Result<()> {
            let mut handles = Vec::new();
            for (t, r) in &groups {
                let rows = &rows;
                handles.push(s.spawn(move || {
                    let policy = zqhero::coordinator::PolicyRef::Named(r.clone());
                    zqhero::bench::closed_loop(
                        fe_ref,
                        t,
                        &policy,
                        rows,
                        per_group,
                        (concurrency / 4).max(4),
                    )
                    .map(|_| ())
                }));
            }
            for h in handles {
                h.join().map_err(|_| anyhow::anyhow!("load thread panicked"))??;
            }
            Ok(())
        })?;
        let cap = (per_group * groups.len()) as f64 / t0.elapsed().as_secs_f64().max(1e-9);
        drop(fe);
        drop(nodes);
        cap
    };
    println!("1-node capacity ~{capacity_rps:.1} req/s through the tier");

    let deadline = Duration::from_millis(250);
    let mut cells: Vec<Value> = Vec::new();
    let mut goodput_by_n: Vec<f64> = Vec::new();
    for n in 1..=max_nodes {
        let (nodes, fe) = start_tier(n)?;
        let rate = 2.0 * capacity_rps * n as f64;
        let arrivals = (rate * 2.0) as usize; // ~2 s of offered overload
        let r = zqhero::bench::open_loop_burst_groups(&fe, &groups, &rows, arrivals, rate, deadline)?;
        anyhow::ensure!(r.reconciles(), "client ledger must reconcile at {n} node(s): {r:?}");
        anyhow::ensure!(
            r.failed == 0,
            "fault-free sweep saw {} typed failures at {n} node(s)",
            r.failed
        );

        // front-tier ledger: per-policy identity, and exact agreement
        // with the client-side ledger
        let (mut fc, mut fsh, mut fex) = (0u64, 0u64, 0u64);
        for s in fe.recorder().snapshot().values() {
            anyhow::ensure!(
                s.requests == s.completed + s.errors + s.expired + s.failed,
                "front-tier ledger identity broken at {n} node(s)"
            );
            fc += s.completed;
            fsh += s.shed;
            fex += s.expired;
        }
        anyhow::ensure!(
            (fc as usize, fsh as usize, fex as usize) == (r.completed, r.shed, r.expired),
            "front recorder disagrees with the client ledger at {n} node(s): \
             ({fc}, {fsh}, {fex}) vs ({}, {}, {})",
            r.completed,
            r.shed,
            r.expired
        );

        // node-tier ledgers: each node's identity holds, the aggregate
        // agrees exactly with the front tier (fault-free run: no retries,
        // so cross-tier counts are equal, not merely >=)
        let (mut nc, mut nex) = (0u64, 0u64);
        for (coord, _) in &nodes {
            for s in coord.recorder.snapshot().values() {
                anyhow::ensure!(
                    s.requests == s.completed + s.errors + s.expired + s.failed,
                    "node-tier ledger identity broken at {n} node(s)"
                );
                nc += s.completed;
                nex += s.expired;
            }
            anyhow::ensure!(coord.queue_depth() == 0, "node backlog slots leaked");
        }
        anyhow::ensure!(
            (nc as usize, nex as usize) == (r.completed, r.expired),
            "tier ledgers disagree at {n} node(s): nodes ({nc} completed, {nex} expired) vs \
             front ({}, {})",
            r.completed,
            r.expired
        );
        anyhow::ensure!(fe.queue_depth() == 0, "front-end backlog slots leaked");

        let goodput = r.goodput_rps();
        println!(
            "{n} node(s): admitted {} = completed {} + shed {} + expired {} + failed {}; \
             goodput {goodput:.1} req/s, p50 {:.1}ms, p99 {:.1}ms",
            r.admitted, r.completed, r.shed, r.expired, r.failed, r.p50_ms, r.p99_ms
        );
        let speedup = goodput / goodput_by_n.first().copied().unwrap_or(goodput).max(1e-9);
        goodput_by_n.push(goodput);
        cells.push(json::obj(vec![
            ("nodes", json::num(n as f64)),
            ("rate_rps", json::num(rate)),
            ("admitted", json::num(r.admitted as f64)),
            ("completed", json::num(r.completed as f64)),
            ("shed", json::num(r.shed as f64)),
            ("expired", json::num(r.expired as f64)),
            ("failed", json::num(r.failed as f64)),
            ("goodput_rps", json::num(goodput)),
            ("p50_ms", json::num(r.p50_ms)),
            ("p99_ms", json::num(r.p99_ms)),
            ("speedup_vs_1", json::num(speedup)),
        ]));
        drop(fe);
        drop(nodes);
    }

    if max_nodes >= 2 {
        let speedup = goodput_by_n[1] / goodput_by_n[0].max(1e-9);
        println!("\n2-node speedup: {speedup:.2}x");
        anyhow::ensure!(
            speedup >= 1.7,
            "multi-host scale-out must reach >=1.7x goodput at 2 engine nodes \
             (got {speedup:.2}x; see BENCH_multihost.json)"
        );
    }

    let report = json::obj(vec![
        ("bench", json::s("multihost")),
        ("groups", json::num(groups.len() as f64)),
        ("fake_engine_ms", json::num(fake_latency.as_millis() as f64)),
        ("capacity_1node_rps", json::num(capacity_rps)),
        ("deadline_ms", json::num(deadline.as_millis() as f64)),
        ("cells", Value::Array(cells)),
    ]);
    match std::fs::write("BENCH_multihost.json", json::to_string_pretty(&report)) {
        Ok(()) => println!("\nwrote BENCH_multihost.json"),
        Err(e) => eprintln!("could not write BENCH_multihost.json: {e}"),
    }
    Ok(())
}

/// `repro lint` — run the herolint static analyses (DESIGN.md §5.11)
/// over the source tree and fail on any unsuppressed finding.  The CI
/// gate (`scripts/ci.sh`) runs this on every checkout; `--json` feeds
/// trend tooling through the in-repo json module.
fn cmd_lint(args: &zqhero::cli::Args) -> Result<()> {
    let flag = args.get_or("src", "src");
    let mut root = PathBuf::from(flag);
    if !root.exists() {
        // `cargo run` may execute from the workspace root rather than
        // the crate dir; fall back to the crate's own tree
        let fallback = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(flag);
        if fallback.exists() {
            root = fallback;
        }
    }
    let report = zqhero::lint::lint_tree(&root)?;
    if args.get_bool("json") {
        println!("{}", zqhero::json::to_string_pretty(&report.to_json()));
    } else {
        print!("{}", report.render());
    }
    // one shared gate for both output modes: `--json` must exit nonzero
    // on findings exactly like the human path (CI keys off the status)
    report.gate()
}
