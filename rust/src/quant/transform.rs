//! Checkpoint transform: fp32 checkpoint + calibration stats -> HERO
//! quantized checkpoint (the production mirror of
//! `python/compile/modeling/quantize.py`; golden tests enforce bit-exact
//! parity, so every numeric convention here matches numpy semantics).

use anyhow::{bail, Context, Result};

use crate::model::container::Container;
use crate::model::manifest::{Manifest, ModelCfg, ModeSpec, PolicySpec, Switches};
use crate::model::tensor::Tensor;

use super::fold::fold_fwq_in_fwq_out;
use super::schemes::{
    clip_absmax_history, quantize_weight_colwise, scale_from_absmax, scale_from_max_nonneg,
};

/// Calibration statistics aggregated across batches (after optional
/// percentile clipping — Discussion (b) of the paper).
#[derive(Debug, Clone)]
pub struct AggStats {
    pub q_absmax: Vec<f64>,        // [L]
    pub k_absmax: Vec<f64>,        // [L]
    pub v_absmax: Vec<f64>,        // [L]
    pub p_max: Vec<f64>,           // [L]
    pub attn_absmax: Vec<Vec<f64>>, // [L][d]
    pub o_absmax: Vec<Vec<f64>>,    // [L][d]
    pub gelu_absmax: Vec<Vec<f64>>, // [L][ffn]
    pub x2_absmax: Vec<Vec<f64>>,   // [L][d]
}

impl AggStats {
    /// Aggregate a per-batch history (stat name -> [batch][flattened])
    /// with percentile clipping at `pct` (100 = running max).
    pub fn from_history(
        hist: &[(String, Vec<Vec<f64>>)],
        cfg: &ModelCfg,
        pct: f64,
    ) -> Result<Self> {
        let find = |name: &str| -> Result<Vec<f64>> {
            let h = &hist
                .iter()
                .find(|(n, _)| n == name)
                .with_context(|| format!("missing stat {name}"))?
                .1;
            Ok(clip_absmax_history(h, pct))
        };
        let per_layer = |flat: Vec<f64>, width: usize| -> Vec<Vec<f64>> {
            flat.chunks(width).map(|c| c.to_vec()).collect()
        };
        let (d, f) = (cfg.hidden, cfg.ffn);
        Ok(AggStats {
            q_absmax: find("q_absmax")?,
            k_absmax: find("k_absmax")?,
            v_absmax: find("v_absmax")?,
            p_max: find("p_max")?,
            attn_absmax: per_layer(find("attn_absmax")?, d),
            o_absmax: per_layer(find("o_absmax")?, d),
            gelu_absmax: per_layer(find("gelu_absmax")?, f),
            x2_absmax: per_layer(find("x2_absmax")?, d),
        })
    }
}

/// Derived activation scales for one layer (paper §2.2).
#[derive(Debug, Clone)]
pub struct LayerScales {
    pub sq_q: f64,
    pub sq_k: f64,
    pub sq_v: f64,
    pub sp: f64,
    pub s_attn: Vec<f32>,
    pub s_o: Vec<f32>,
    pub s_a: Vec<f32>,
    pub s_x2: Vec<f32>,
}

pub fn derive_layer_scales(stats: &AggStats, i: usize) -> LayerScales {
    let vecf32 =
        |v: &[f64]| -> Vec<f32> { v.iter().map(|a| scale_from_absmax(*a) as f32).collect() };
    LayerScales {
        sq_q: scale_from_absmax(stats.q_absmax[i]),
        sq_k: scale_from_absmax(stats.k_absmax[i]),
        sq_v: scale_from_absmax(stats.v_absmax[i]),
        sp: scale_from_max_nonneg(stats.p_max[i]),
        s_attn: vecf32(&stats.attn_absmax[i]),
        s_o: vecf32(&stats.o_absmax[i]),
        s_a: vecf32(&stats.gelu_absmax[i]),
        s_x2: vecf32(&stats.x2_absmax[i]),
    }
}

fn get2(fp: &Container, name: &str) -> Result<(Vec<f32>, usize, usize)> {
    let t = fp.get(name).with_context(|| format!("missing fp param {name}"))?;
    if t.shape.len() != 2 {
        bail!("{name}: expected 2-D, got {:?}", t.shape);
    }
    Ok((t.as_f32()?.to_vec(), t.shape[0], t.shape[1]))
}

fn get1(fp: &Container, name: &str) -> Result<Vec<f32>> {
    Ok(fp
        .get(name)
        .with_context(|| format!("missing fp param {name}"))?
        .as_f32()?
        .to_vec())
}

/// fp32 checkpoint + aggregated stats -> quantized checkpoint in
/// `hero_param_specs` order for the given switches.
pub fn quantize_checkpoint(
    fp: &Container,
    stats: &AggStats,
    cfg: &ModelCfg,
    sw: &Switches,
) -> Result<Container> {
    let (d, f, h) = (cfg.hidden, cfg.ffn, cfg.heads);
    let dh = cfg.head_dim();
    let mut out = Container::new();

    for name in ["emb.tok", "emb.pos", "emb.type", "emb.ln.g", "emb.ln.b"] {
        out.push(name, fp.get(name).with_context(|| name.to_string())?.clone());
    }

    for i in 0..cfg.layers {
        let p = format!("L{i}.");
        let sc = derive_layer_scales(stats, i);
        let sq_of = |t: char| match t {
            'q' => sc.sq_q,
            'k' => sc.sq_k,
            'v' => sc.sq_v,
            _ => unreachable!(),
        };

        // ---- QKV projections
        if sw.qkv {
            for t in ['q', 'k', 'v'] {
                let (w, k_, m_) = get2(fp, &format!("{p}attn.{t}.w"))?;
                let b = get1(fp, &format!("{p}attn.{t}.b"))?;
                if sw.attn {
                    // eq. 20-21: fold SQ output scale; numpy divides the
                    // f32 weight by the weak f64 scalar in f32.
                    let s = sq_of(t) as f32;
                    let wt: Vec<f32> = w.iter().map(|x| x / s).collect();
                    let (wq, ws) = quantize_weight_colwise(&wt, k_, m_);
                    out.push(&format!("{p}attn.{t}.wq"), Tensor::i8(vec![k_, m_], wq));
                    out.push(&format!("{p}attn.{t}.ws"), Tensor::f32(vec![m_], ws));
                    out.push(
                        &format!("{p}attn.{t}.b"),
                        Tensor::f32(vec![d], b.iter().map(|x| x / s).collect()),
                    );
                } else {
                    let (wq, ws) = quantize_weight_colwise(&w, k_, m_);
                    out.push(&format!("{p}attn.{t}.wq"), Tensor::i8(vec![k_, m_], wq));
                    out.push(&format!("{p}attn.{t}.ws"), Tensor::f32(vec![m_], ws));
                    out.push(&format!("{p}attn.{t}.b"), Tensor::f32(vec![d], b));
                }
            }
        } else {
            for t in ['q', 'k', 'v'] {
                out.push(
                    &format!("{p}attn.{t}.w"),
                    fp.get(&format!("{p}attn.{t}.w")).context("qkv w")?.clone(),
                );
                out.push(
                    &format!("{p}attn.{t}.b"),
                    fp.get(&format!("{p}attn.{t}.b")).context("qkv b")?.clone(),
                );
            }
        }

        // ---- attention core scales
        if sw.attn {
            let qk = (sc.sq_q * sc.sq_k / (dh as f64).sqrt()) as f32;
            out.push(&format!("{p}attn.qk_scale"), Tensor::f32(vec![1], vec![qk]));
            out.push(&format!("{p}attn.sp"), Tensor::f32(vec![1], vec![sc.sp as f32]));
            // pv = (sp * S_v) / S_attn — weak f64 scalar hits the f32 array
            let num = (sc.sp * sc.sq_v) as f32;
            let pv: Vec<f32> = sc.s_attn.iter().map(|s| num / s).collect();
            out.push(&format!("{p}attn.pv_scale"), Tensor::f32(vec![h, dh], pv));
            if !sw.qkv {
                for t in ['q', 'k', 'v'] {
                    out.push(
                        &format!("{p}attn.inv_sq_{t}"),
                        Tensor::f32(vec![1], vec![(1.0 / sq_of(t)) as f32]),
                    );
                }
            }
        }

        // ---- attention output projection
        if sw.attn_output {
            let (w, k_, m_) = get2(fp, &format!("{p}attn.o.w"))?;
            let b = get1(fp, &format!("{p}attn.o.b"))?;
            let (wt, bt) = fold_fwq_in_fwq_out(&w, &b, &sc.s_attn, &sc.s_o, k_, m_);
            let (wq, ws) = quantize_weight_colwise(&wt, k_, m_);
            out.push(&format!("{p}attn.o.wq"), Tensor::i8(vec![k_, m_], wq));
            out.push(&format!("{p}attn.o.ws"), Tensor::f32(vec![m_], ws));
            out.push(&format!("{p}attn.o.bq"), Tensor::f32(vec![d], bt));
            out.push(&format!("{p}ln1.so"), Tensor::f32(vec![d], sc.s_o.clone()));
            if !sw.attn {
                let inv: Vec<f32> = sc.s_attn.iter().map(|s| 1.0 / s).collect();
                out.push(&format!("{p}attn.inv_s_attn"), Tensor::f32(vec![d], inv));
            }
        } else {
            out.push(
                &format!("{p}attn.o.w"),
                fp.get(&format!("{p}attn.o.w")).context("o.w")?.clone(),
            );
            out.push(
                &format!("{p}attn.o.b"),
                fp.get(&format!("{p}attn.o.b")).context("o.b")?.clone(),
            );
            if sw.attn {
                out.push(&format!("{p}attn.s_attn"), Tensor::f32(vec![d], sc.s_attn.clone()));
            }
        }
        out.push(&format!("{p}ln1.g"), fp.get(&format!("{p}ln1.g")).context("ln1.g")?.clone());
        out.push(&format!("{p}ln1.b"), fp.get(&format!("{p}ln1.b")).context("ln1.b")?.clone());

        // ---- MLP
        if sw.fc1 {
            let (w, k_, m_) = get2(fp, &format!("{p}fc1.w"))?;
            let (wq, ws) = quantize_weight_colwise(&w, k_, m_);
            out.push(&format!("{p}fc1.wq"), Tensor::i8(vec![k_, m_], wq));
            out.push(&format!("{p}fc1.ws"), Tensor::f32(vec![m_], ws));
            out.push(&format!("{p}fc1.b"), fp.get(&format!("{p}fc1.b")).context("fc1.b")?.clone());
        } else {
            out.push(&format!("{p}fc1.w"), fp.get(&format!("{p}fc1.w")).context("fc1.w")?.clone());
            out.push(&format!("{p}fc1.b"), fp.get(&format!("{p}fc1.b")).context("fc1.b")?.clone());
        }
        if sw.fc2 {
            out.push(&format!("{p}gelu.sa"), Tensor::f32(vec![f], sc.s_a.clone()));
            let (w, k_, m_) = get2(fp, &format!("{p}fc2.w"))?;
            let b = get1(fp, &format!("{p}fc2.b"))?;
            let (wt, bt) = fold_fwq_in_fwq_out(&w, &b, &sc.s_a, &sc.s_x2, k_, m_);
            let (wq, ws) = quantize_weight_colwise(&wt, k_, m_);
            out.push(&format!("{p}fc2.wq"), Tensor::i8(vec![k_, m_], wq));
            out.push(&format!("{p}fc2.ws"), Tensor::f32(vec![m_], ws));
            out.push(&format!("{p}fc2.bq"), Tensor::f32(vec![d], bt));
            out.push(&format!("{p}ln2.sx2"), Tensor::f32(vec![d], sc.s_x2.clone()));
        } else {
            out.push(&format!("{p}fc2.w"), fp.get(&format!("{p}fc2.w")).context("fc2.w")?.clone());
            out.push(&format!("{p}fc2.b"), fp.get(&format!("{p}fc2.b")).context("fc2.b")?.clone());
        }
        out.push(&format!("{p}ln2.g"), fp.get(&format!("{p}ln2.g")).context("ln2.g")?.clone());
        out.push(&format!("{p}ln2.b"), fp.get(&format!("{p}ln2.b")).context("ln2.b")?.clone());
    }

    for name in ["pool.w", "pool.b", "cls.w", "cls.b"] {
        out.push(name, fp.get(name).with_context(|| name.to_string())?.clone());
    }
    Ok(out)
}

/// Validate a quantized checkpoint against a precision policy: the
/// checkpoint must carry the signature of the policy's *executable* mode
/// (per-module overrides change which artifact serves the request, never
/// the checkpoint layout of that artifact).  The error names the policy
/// and, when escalation kicked in, the effective-vs-executed switch tags
/// so a mismatch is debuggable from the message alone.
pub fn validate_for_policy(ckpt: &Container, man: &Manifest, policy: &PolicySpec) -> Result<()> {
    let mode = man.mode_by_id(policy.exec_mode);
    validate_against_mode(ckpt, mode).with_context(|| {
        format!(
            "policy {:?} (effective switches {}, executes mode {:?} / {})",
            policy.name,
            policy.effective.tag(),
            mode.name,
            mode.switches.tag()
        )
    })
}

/// Validate a quantized checkpoint against the manifest's mode signature:
/// same names, same order, same shapes, same dtypes.
pub fn validate_against_mode(ckpt: &Container, mode: &ModeSpec) -> Result<()> {
    if ckpt.len() != mode.params.len() {
        bail!(
            "checkpoint has {} tensors, mode {} expects {}",
            ckpt.len(),
            mode.name,
            mode.params.len()
        );
    }
    for ((name, t), spec) in ckpt.entries.iter().zip(&mode.params) {
        if name != &spec.name {
            bail!("param order mismatch: checkpoint {name:?} vs manifest {:?}", spec.name);
        }
        if t.shape != spec.shape {
            bail!("{name}: shape {:?} vs manifest {:?}", t.shape, spec.shape);
        }
        if t.dtype() != spec.dtype {
            bail!("{name}: dtype {:?} vs manifest {:?}", t.dtype(), spec.dtype);
        }
    }
    Ok(())
}
