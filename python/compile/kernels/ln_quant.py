"""``LN^quant`` — fused LayerNorm with quantization-aware inputs/outputs
(paper eqs. 7, 19, 31) plus the standalone TWQ quantizer, as Pallas kernels.

TPU adaptation (DESIGN.md §7): the CUDA implementation computes per-token
min/max in registers during the LN epilogue; here each grid step owns a
``[block_tokens, d]`` tile resident in VMEM, computes mean/variance/absmax
on the VPU in one pass over the tile, and writes the INT8 tile plus the
``[block_tokens, 1]`` TWQ scale vector.  HBM traffic is one read of the
inputs and one *INT8* write of the output — the paper's ~2x data-volume
reduction for the downstream GeMM read.

All kernels run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); the BlockSpec structure is the TPU schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QMAX = 127.0
# 256-token tiles: [256, d] f32 = 128 KB in VMEM (d=128) — far under the
# ~16 MB budget, 8x fewer grid steps than the original 32-token tiles
# (interpret-mode grid steps dominate CPU cost; on TPU bigger tiles also
# amortize the HBM->VMEM pipeline better).  Perf log: EXPERIMENTS.md §Perf.
DEFAULT_BLOCK_TOKENS = 256


def _pick_block(n, want=DEFAULT_BLOCK_TOKENS):
    """Largest divisor of n that is <= want (shapes here are powers of two)."""
    b = min(n, want)
    while n % b:
        b -= 1
    return b


def _ln_rows(x, gamma, beta, eps):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta


def _twq_rows(y):
    absmax = jnp.max(jnp.abs(y), axis=-1, keepdims=True)
    s = jnp.maximum(absmax, 1e-10) / QMAX
    q = jnp.clip(jnp.round(y / s), -QMAX, QMAX).astype(jnp.int8)
    return q, s.astype(jnp.float32)


# --------------------------------------------------------------------------
# standalone TWQ quantizer (the "additional kernel invocation" the paper
# wants to avoid by fusing; kept for mode fallbacks and as a baseline)
# --------------------------------------------------------------------------


def _twq_kernel(x_ref, q_ref, s_ref):
    q, s = _twq_rows(x_ref[...])
    q_ref[...] = q
    s_ref[...] = s


def twq_quantize(x, *, block_tokens=None):
    """f32 [n,d] -> (int8 [n,d], scales f32 [n,1])."""
    n, d = x.shape
    bt = block_tokens or _pick_block(n)
    return pl.pallas_call(
        _twq_kernel,
        grid=(n // bt,),
        in_specs=[pl.BlockSpec((bt, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.int8),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        interpret=True,
    )(x)


# --------------------------------------------------------------------------
# fused residual LN^quant
# --------------------------------------------------------------------------


def _ln_kernel(*refs, a_quant, b_quant, quantize_out, eps):
    """Ref order: [a, a_s?, b, b_s?, gamma, beta] -> [y(|q), s?]."""
    it = iter(refs)
    a_ref = next(it)
    a_s = next(it) if a_quant else None
    b_ref = next(it)
    b_s = next(it) if b_quant else None
    gamma_ref = next(it)
    beta_ref = next(it)
    outs = list(it)

    af = a_ref[...].astype(jnp.float32)
    if a_quant:
        af = af * a_s[...]  # TWQ [bt,1]
    bf = b_ref[...].astype(jnp.float32)
    if b_quant:
        bf = bf * b_s[...]  # FWQ [1,d]
    y = _ln_rows(af + bf, gamma_ref[...], beta_ref[...], eps)
    if quantize_out:
        q, s = _twq_rows(y)
        outs[0][...] = q
        outs[1][...] = s
    else:
        outs[0][...] = y


def ln_quant(a, b, gamma, beta, *, a_scale=None, b_scale=None,
             quantize_out=True, eps=1e-12, block_tokens=None):
    """Fused residual LayerNorm (paper eq. 19/31).

    ``a``: residual input, f32 [n,d] or int8 with TWQ ``a_scale`` [n,1].
    ``b``: branch output, f32 [n,d] or int8 with FWQ ``b_scale`` [1,d].
    Returns (y_int8 [n,d], s [n,1]) if ``quantize_out`` else y f32 [n,d].
    """
    n, d = a.shape
    bt = block_tokens or _pick_block(n)
    a_quant = a_scale is not None
    b_quant = b_scale is not None

    args, in_specs = [a], [pl.BlockSpec((bt, d), lambda i: (i, 0))]
    if a_quant:
        args.append(a_scale)
        in_specs.append(pl.BlockSpec((bt, 1), lambda i: (i, 0)))
    args.append(b)
    in_specs.append(pl.BlockSpec((bt, d), lambda i: (i, 0)))
    if b_quant:
        args.append(b_scale.reshape(1, d))
        in_specs.append(pl.BlockSpec((1, d), lambda i: (0, 0)))
    args += [gamma.reshape(1, d), beta.reshape(1, d)]
    in_specs += [pl.BlockSpec((1, d), lambda i: (0, 0))] * 2

    if quantize_out:
        out_specs = [
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        ]
        out_shape = [
            jax.ShapeDtypeStruct((n, d), jnp.int8),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ]
    else:
        out_specs = [pl.BlockSpec((bt, d), lambda i: (i, 0))]
        out_shape = [jax.ShapeDtypeStruct((n, d), jnp.float32)]

    kernel = functools.partial(
        _ln_kernel, a_quant=a_quant, b_quant=b_quant,
        quantize_out=quantize_out, eps=eps,
    )
    out = pl.pallas_call(
        kernel, grid=(n // bt,), in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=True,
    )(*args)
    return (out[0], out[1]) if quantize_out else out[0]


def ln_quant_embed(x_t, x_pb, gamma, beta, *, t_scale=None, quantize_out=True,
                   eps=1e-12, block_tokens=None):
    """Embedding LN (paper eq. 7): ``LN(X_t + (X_p + X_s))``.

    ``x_t`` may be TWQ int8 (``t_scale`` [n,1]) — the paper quantizes the
    token-embedding gather output to halve the LN input volume; ``x_pb`` is
    the (small) position+type sum, f32.
    """
    # Same kernel family: a = X_t (TWQ or f32), b = X_p + X_s (f32).
    return ln_quant(
        x_t, x_pb, gamma, beta, a_scale=t_scale, b_scale=None,
        quantize_out=quantize_out, eps=eps, block_tokens=block_tokens,
    )
