//! Runtime integration over real artifacts: HLO load/compile, device
//! checkpoints, batch-bucket consistency, calibration execution.
//! Gated on `make artifacts`.

use std::path::{Path, PathBuf};

use zqhero::data::{batches, Split};
use zqhero::model::manifest::Manifest;
use zqhero::model::Container;
use zqhero::runtime::Runtime;

fn artifacts() -> Option<PathBuf> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p.to_path_buf())
    } else {
        eprintln!("skipping runtime integration tests: run `make artifacts` first");
        None
    }
}

fn runtime(dir: &Path) -> Runtime {
    Runtime::new(Manifest::load(dir).unwrap()).unwrap()
}

#[test]
fn fp_inference_runs_and_buckets_agree() {
    let Some(dir) = artifacts() else { return };
    let mut rt = runtime(&dir);
    let task = rt.manifest.task("cola").unwrap().clone();
    let fp = Container::read_file(&rt.manifest.path(&task.checkpoint))
        .unwrap()
        .reordered(&rt.manifest.mode("fp").unwrap().params)
        .unwrap();
    rt.upload_checkpoint(&task.name, "fp", &fp).unwrap();

    let split = Split::load(&rt.manifest, &task, "dev").unwrap();
    let nl = rt.manifest.model.num_labels;
    let seq = rt.manifest.seq;

    // one example through bucket 1
    let (ids, tys) = split.row(0);
    let mask = Split::mask_row(ids);
    let l1 = rt
        .infer(&task.name, "fp", 1, ids, tys, &mask)
        .unwrap();
    let l1 = l1.as_f32().unwrap().to_vec();
    assert_eq!(l1.len(), nl);
    assert!(l1.iter().all(|x| x.is_finite()));

    // same example as row 0 of a padded bucket-4 batch
    let mut ids4 = ids.to_vec();
    let mut tys4 = tys.to_vec();
    ids4.resize(4 * seq, 0);
    tys4.resize(4 * seq, 0);
    let mask4 = Split::mask_row(&ids4);
    let l4 = rt.infer(&task.name, "fp", 4, &ids4, &tys4, &mask4).unwrap();
    let l4 = l4.as_f32().unwrap();
    for i in 0..nl {
        assert!(
            (l1[i] - l4[i]).abs() < 1e-4,
            "bucket 1 vs 4 logit {i}: {} vs {}",
            l1[i],
            l4[i]
        );
    }

    // the policy wrapper must agree with mode-name inference exactly:
    // a uniform policy name resolves to the same executable
    let lp = rt.infer_policy(&task.name, "fp", 1, ids, tys, &mask).unwrap();
    let lp = lp.as_f32().unwrap();
    for i in 0..nl {
        assert_eq!(l1[i], lp[i], "policy wrapper diverged at logit {i}");
    }
    // unknown policy names fail with the known-policy list
    let err = rt
        .infer_policy(&task.name, "nope", 1, ids, tys, &mask)
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown policy"), "{err}");
}

#[test]
fn quantized_modes_run_and_track_fp() {
    let Some(dir) = artifacts() else { return };
    let mut rt = runtime(&dir);
    let task = rt.manifest.task("sst2").unwrap().clone();

    // derive quantized checkpoints through the real pipeline (small calib)
    let hist = zqhero::evalharness::ensure_calibration(&mut rt, &task, 4, false).unwrap();
    let fp = Container::read_file(&rt.manifest.path(&task.checkpoint))
        .unwrap()
        .reordered(&rt.manifest.mode("fp").unwrap().params)
        .unwrap();
    rt.upload_checkpoint(&task.name, "fp", &fp).unwrap();

    let split = Split::load(&rt.manifest, &task, "dev").unwrap();
    let nl = rt.manifest.model.num_labels;
    let b = &batches(&split, 16)[0];
    let fp_logits = rt
        .infer(&task.name, "fp", 16, &b.ids, &b.type_ids, &b.mask)
        .unwrap();
    let fp_v = fp_logits.as_f32().unwrap().to_vec();

    for mode in ["m1", "m2", "m3"] {
        let ckpt =
            zqhero::evalharness::quantize_task(&mut rt, &task, mode, &hist, 100.0, Some("test"))
                .unwrap();
        rt.upload_checkpoint(&task.name, mode, &ckpt).unwrap();
        let lq = rt
            .infer(&task.name, mode, 16, &b.ids, &b.type_ids, &b.mask)
            .unwrap();
        let qv = lq.as_f32().unwrap();
        assert!(qv.iter().all(|x| x.is_finite()), "{mode}: non-finite logits");
        // predictions should mostly agree with fp on real data
        let mut agree = 0;
        for row in 0..b.real {
            let arg = |v: &[f32]| {
                let s = &v[row * nl..row * nl + 2];
                if s[0] >= s[1] {
                    0
                } else {
                    1
                }
            };
            if arg(&fp_v) == arg(qv) {
                agree += 1;
            }
        }
        assert!(
            agree * 10 >= b.real * 8,
            "{mode}: only {agree}/{} predictions agree with fp",
            b.real
        );
    }
}

#[test]
fn calibration_artifact_returns_manifest_stats() {
    let Some(dir) = artifacts() else { return };
    let mut rt = runtime(&dir);
    let task = rt.manifest.task("mrpc").unwrap().clone();
    let hist = zqhero::evalharness::ensure_calibration(&mut rt, &task, 2, true).unwrap();
    let stats: Vec<(String, Vec<usize>)> = rt.manifest.calib.stats.clone();
    assert_eq!(hist.len(), stats.len());
    for ((name, per_batch), (mname, shape)) in hist.iter().zip(&stats) {
        assert_eq!(name, mname);
        assert_eq!(per_batch.len(), 2);
        let numel: usize = shape.iter().product();
        assert_eq!(per_batch[0].len(), numel, "{name}");
        assert!(per_batch[0].iter().all(|v| v.is_finite() && *v >= 0.0), "{name}");
    }
    // p_max is a probability
    let p = hist.iter().find(|(n, _)| n == "p_max").unwrap();
    assert!(p.1.iter().flatten().all(|v| *v <= 1.0 + 1e-6));
}

#[test]
fn rust_fp_eval_matches_python_training_eval() {
    // The FP dev metric computed through the rust runtime + artifacts must
    // match the python-side value recorded at training time (same split,
    // same weights, same math in f32) within a small tolerance.
    let Some(dir) = artifacts() else { return };
    let src = std::fs::read_to_string(dir.join("checkpoints/sst2/train_metrics.json")).unwrap();
    let py = zqhero::json::parse(&src).unwrap();
    let py_acc = py.get("acc").unwrap().as_f64().unwrap();

    let mut rt = runtime(&dir);
    let task = rt.manifest.task("sst2").unwrap().clone();
    let vals = zqhero::evalharness::eval_task(&mut rt, &task, "fp", 1, 100.0).unwrap();
    let rust_acc = vals["acc"];
    assert!(
        (rust_acc - py_acc).abs() < 0.02,
        "rust {rust_acc} vs python {py_acc}"
    );
}
