//! L3 coordinator: the paper's missing "end-to-end system" — request
//! routing, dynamic batching, per-request precision modes, backpressure,
//! and serving metrics over the PJRT engine thread.

pub mod batcher;
pub mod net;
pub mod request;
pub mod server;
pub mod stats;

pub use batcher::{Batch, Batcher};
pub use request::{GroupKey, Request, Response, Timing};
pub use server::{checkpoint_rel, Coordinator, ServerConfig};
pub use net::{NetClient, NetServer};
pub use stats::{Histogram, Recorder};
