//! Pipelined-engine integration: mixed (task, mode, bucket) traffic
//! through the overlapped upload/execute/readback stages, asserting
//! per-group request order (via the batch_seq FIFO witness, generalized
//! to the replica pool by the per-replica engine_seq witness), logit
//! parity with the blocking pre-pipeline path, engine timing coherence
//! (`upload_us + exec_us <= engine_us <= total_us` — the exec clock must
//! not double-count the upload), drain-on-drop with N>1 replicas, and
//! panic isolation in the readback/completion stage.  Gated on
//! `make artifacts`.

mod common;

use std::collections::HashMap;
use std::time::Duration;

use common::{artifacts, ensure_quantized};
use zqhero::coordinator::{Coordinator, RequestSpec, Response, ServerConfig};
use zqhero::data::Split;
use zqhero::evalharness as eh;
use zqhero::model::manifest::Manifest;
use zqhero::runtime::{FaultPlan, Runtime};

fn config(pipeline: bool) -> ServerConfig {
    ServerConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        pipeline,
        ..ServerConfig::default()
    }
}

/// Engine-side timing coherence for one response: the exec clock starts
/// after the upload returns (no double-count) and the whole-job engine
/// time nests inside the end-to-end time.
fn assert_timing_coherent(resp: &Response, ctx: &str) {
    let t = &resp.timing;
    assert!(
        t.upload_us + t.exec_us <= t.engine_us,
        "{ctx}: upload {} + exec {} > engine total {} (exec clock double-counts the upload?)",
        t.upload_us,
        t.exec_us,
        t.engine_us
    );
    assert!(
        t.engine_us <= t.total_us,
        "{ctx}: engine {} > end-to-end {}",
        t.engine_us,
        t.total_us
    );
}

/// Per-group FIFO witnesses over one group's responses: submit order
/// (request id order) rides non-decreasing batcher dispatch numbers, and
/// same-replica batches execute in submit order (per-replica engine_seq
/// is stamped in execution order).  Valid for 1 and N replicas.
fn assert_group_fifo(group: &[Response], n_replicas: usize, ctx: &str) {
    let mut by_id: Vec<&Response> = group.iter().collect();
    by_id.sort_unstable_by_key(|r| r.id);
    let seqs: Vec<u64> = by_id.iter().map(|r| r.timing.batch_seq).collect();
    let mut sorted = seqs.clone();
    sorted.sort_unstable();
    assert_eq!(seqs, sorted, "{ctx}: replies out of batch order");
    let mut last_exec: HashMap<usize, u64> = HashMap::new();
    for r in &by_id {
        let rep = r.timing.replica;
        assert!(rep < n_replicas, "{ctx}: replica {rep} out of range");
        if let Some(prev) = last_exec.insert(rep, r.timing.engine_seq) {
            assert!(
                r.timing.engine_seq >= prev,
                "{ctx}: replica {rep} ran batch {} after {} against submit order (req {})",
                r.timing.engine_seq,
                prev,
                r.id
            );
        }
    }
}

/// Flood mixed traffic; returns per-group (submit-order ids, responses).
fn flood(
    coord: &Coordinator,
    routes: &[(&str, &str)],
    payload: &[(Vec<i32>, Vec<i32>)],
    per_route: usize,
) -> Vec<Vec<Response>> {
    // interleave with varying burst sizes so batches land in different
    // buckets: 1, 2, 5, 1, 2, 5, ...
    let bursts = [1usize, 2, 5];
    let mut rxs: Vec<Vec<std::sync::mpsc::Receiver<Response>>> =
        routes.iter().map(|_| Vec::new()).collect();
    let mut sent = vec![0usize; routes.len()];
    let mut b = 0;
    while sent.iter().any(|s| *s < per_route) {
        for (gi, &(task, mode)) in routes.iter().enumerate() {
            let burst = bursts[b % bursts.len()].min(per_route - sent[gi]);
            for _ in 0..burst {
                let (ids, tys) = payload[sent[gi] % payload.len()].clone();
                let rx = coord
                    .submit(RequestSpec::task(task).policy(mode).ids(ids).type_ids(tys))
                    .expect("admitted");
                rxs[gi].push(rx);
                sent[gi] += 1;
            }
        }
        b += 1;
        // small gap so the batcher's max_wait can slice bursts into
        // different batch sizes
        std::thread::sleep(Duration::from_millis(1));
    }
    rxs.into_iter()
        .map(|group| {
            group
                .into_iter()
                .map(|rx| rx.recv_timeout(Duration::from_secs(120)).expect("reply"))
                .collect()
        })
        .collect()
}

#[test]
fn pipelined_mixed_traffic_fifo_and_parity() {
    let Some(dir) = artifacts() else { return };
    ensure_quantized(&dir, "sst2", "m3");

    let routes = [("cola", "fp"), ("sst2", "fp"), ("sst2", "m3")];
    let pairs: Vec<(String, String)> =
        routes.iter().map(|(t, m)| (t.to_string(), m.to_string())).collect();

    let man = Manifest::load(&dir).unwrap();
    let split = Split::load(&man, man.task("cola").unwrap(), "dev").unwrap();
    let n_rows = 24.min(split.len());
    let payload: Vec<(Vec<i32>, Vec<i32>)> = (0..n_rows)
        .map(|i| {
            let (a, b) = split.row(i);
            (a.to_vec(), b.to_vec())
        })
        .collect();

    let per_route = 30;
    let piped = {
        let coord = Coordinator::start(dir.clone(), &pairs, config(true)).unwrap();
        flood(&coord, &routes, &payload, per_route)
    };

    for (gi, group) in piped.iter().enumerate() {
        assert_eq!(group.len(), per_route);
        for resp in group {
            assert!(resp.error.is_none(), "group {gi}: {:?}", resp.error);
            assert_eq!(resp.logits.len(), man.model.num_labels);
            assert!(resp.logits.iter().all(|x| x.is_finite()));
            assert!(resp.timing.bucket >= resp.timing.batch_real);
            assert!(resp.timing.batch_real >= 1 && resp.timing.batch_real <= 8);
            assert_timing_coherent(resp, &format!("group {gi} req {}", resp.id));
        }
        // FIFO witness: within a group, submit order (request id order)
        // must ride non-decreasing dispatch sequence numbers — the
        // overlapped engine must not reorder batches of a group.
        assert_group_fifo(group, 1, &format!("group {gi}"));
    }

    // numeric parity: the overlapped engine must match the blocking
    // (pre-pipeline) engine loop exactly — same artifacts, same inputs.
    let blocking = {
        let coord = Coordinator::start(dir.clone(), &pairs, config(false)).unwrap();
        flood(&coord, &routes, &payload, per_route)
    };
    for (gp, gb) in piped.iter().zip(&blocking) {
        for (rp, rb) in gp.iter().zip(gb) {
            for (a, b) in rp.logits.iter().zip(&rb.logits) {
                assert!(
                    (a - b).abs() < 1e-4,
                    "pipelined {a} vs blocking {b} (req {} / {})",
                    rp.id,
                    rb.id
                );
            }
        }
    }

    // parity with direct single-row runtime inference (absolute truth)
    let mut rt = Runtime::new(Manifest::load(&dir).unwrap()).unwrap();
    let cola = rt.manifest.task("cola").unwrap().clone();
    eh::ensure_checkpoint(&mut rt, &cola, "fp", 4, 100.0).unwrap();
    for i in 0..4 {
        let (ids, tys) = split.row(i);
        let mask = Split::mask_row(ids);
        let direct = rt.infer("cola", "fp", 1, ids, tys, &mask).unwrap();
        let dv = direct.as_f32().unwrap();
        // group 0 is cola/fp; its i-th submission used payload row i
        for (a, b) in piped[0][i].logits.iter().zip(dv) {
            assert!((a - b).abs() < 1e-3, "req {i}: pipelined {a} vs direct {b}");
        }
    }
}

/// Tentpole acceptance: mixed traffic over a 2-replica engine pool keeps
/// per-group FIFO order (pinning + per-replica execution serials), lands
/// every batch on a valid replica with accounting that sums to the
/// per-policy totals, and matches single-replica logits exactly.
#[test]
fn replica_pool_mixed_traffic_fifo_accounting_and_parity() {
    let Some(dir) = artifacts() else { return };
    ensure_quantized(&dir, "sst2", "m3");

    let routes = [("cola", "fp"), ("sst2", "fp"), ("sst2", "m3")];
    let pairs: Vec<(String, String)> =
        routes.iter().map(|(t, m)| (t.to_string(), m.to_string())).collect();

    let man = Manifest::load(&dir).unwrap();
    let split = Split::load(&man, man.task("cola").unwrap(), "dev").unwrap();
    let n_rows = 24.min(split.len());
    let payload: Vec<(Vec<i32>, Vec<i32>)> = (0..n_rows)
        .map(|i| {
            let (a, b) = split.row(i);
            (a.to_vec(), b.to_vec())
        })
        .collect();

    let per_route = 30;
    let n_replicas = 2;
    let (pooled, reps, dispatched_groups) = {
        let coord = Coordinator::start(
            dir.clone(),
            &pairs,
            ServerConfig { replicas: n_replicas, ..config(true) },
        )
        .unwrap();
        assert_eq!(coord.engine().replicas(), n_replicas);
        let groups = flood(&coord, &routes, &payload, per_route);
        // after all replies, nothing is in flight and no group is pinned
        let ds = coord.engine().dispatch_state();
        for r in 0..n_replicas {
            assert_eq!(ds.inflight(r), 0, "replica {r} leaked in-flight accounting");
        }
        assert_eq!(ds.pinned_groups(), 0, "drained groups must unpin");
        (groups, coord.recorder.replica_snapshot(), coord.recorder.snapshot())
    };

    for (gi, group) in pooled.iter().enumerate() {
        assert_eq!(group.len(), per_route);
        for resp in group {
            assert!(resp.error.is_none(), "group {gi}: {:?}", resp.error);
            assert!(resp.logits.iter().all(|x| x.is_finite()));
            assert_timing_coherent(resp, &format!("pool group {gi} req {}", resp.id));
        }
        assert_group_fifo(group, n_replicas, &format!("pool group {gi}"));
    }

    // per-replica batch counters sum to the per-policy batch totals
    assert_eq!(reps.len(), n_replicas);
    let total_batches: u64 = dispatched_groups.values().map(|s| s.batches).sum();
    assert_eq!(
        reps.iter().map(|r| r.batches).sum::<u64>(),
        total_batches,
        "per-replica counts must sum to total batches: {reps:?}"
    );
    let total_rows: u64 = dispatched_groups.values().map(|s| s.batched_rows).sum();
    assert_eq!(reps.iter().map(|r| r.rows).sum::<u64>(), total_rows);

    // numeric parity: the pool must serve the exact same logits as a
    // single-replica coordinator over the same artifacts and inputs
    let single = {
        let coord = Coordinator::start(dir.clone(), &pairs, config(true)).unwrap();
        flood(&coord, &routes, &payload, per_route)
    };
    for (gp, gs) in pooled.iter().zip(&single) {
        for (rp, rs) in gp.iter().zip(gs) {
            for (a, b) in rp.logits.iter().zip(&rs.logits) {
                assert!(
                    (a - b).abs() < 1e-4,
                    "pool {a} vs single {b} (req {} / {})",
                    rp.id,
                    rs.id
                );
            }
        }
    }
}

/// Shutdown drain with N>1: every admitted request still gets a reply
/// when the coordinator drops immediately after the submit burst — the
/// batcher drains into the pool, each replica drains its queue, and the
/// worker pool runs every completion before joining.
#[test]
fn replica_pool_drains_on_drop() {
    let Some(dir) = artifacts() else { return };
    let pairs = vec![("cola".to_string(), "fp".to_string())];
    let coord = Coordinator::start(
        dir.clone(),
        &pairs,
        ServerConfig {
            replicas: 3,
            max_batch: 4,
            // long enough that undispatched requests are still queued in
            // the batcher when the drop begins — the drain must flush them
            max_wait: Duration::from_millis(250),
            ..ServerConfig::default()
        },
    )
    .unwrap();

    let man = Manifest::load(&dir).unwrap();
    let split = Split::load(&man, man.task("cola").unwrap(), "dev").unwrap();
    let mut rxs = Vec::new();
    for i in 0..22 {
        let (ids, tys) = split.row(i % split.len());
        let rx = coord
            .submit(RequestSpec::task("cola").mode("fp").ids(ids.to_vec()).type_ids(tys.to_vec()))
            .unwrap();
        rxs.push(rx);
    }
    drop(coord);
    // after drop returns, every reply has been sent (or its sender
    // dropped); recv must not block and must carry a real answer
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(1))
            .unwrap_or_else(|e| panic!("request {i} lost in shutdown drain: {e}"));
        assert!(resp.error.is_none(), "request {i}: {:?}", resp.error);
        assert!(!resp.logits.is_empty());
    }
}

/// Tentpole acceptance (DESIGN.md §5.9): mixed-length traffic batches
/// per sequence-length class — every response's seq bucket is the
/// smallest manifest bucket that fits its request, the per-batch padding
/// ledger is coherent, and logits match direct single-row inference at
/// the same seq bucket.
#[test]
fn mixed_length_traffic_buckets_and_parity() {
    let Some(dir) = artifacts() else { return };
    let man = Manifest::load(&dir).unwrap();
    if man.num_seq_buckets() == 1 {
        eprintln!("skipping mixed-length test: single-seq manifest (format_version 2 artifacts)");
        return;
    }
    let pairs = vec![("cola".to_string(), "fp".to_string())];
    let coord = Coordinator::start(dir.clone(), &pairs, config(true)).unwrap();
    let split = Split::load(&man, man.task("cola").unwrap(), "dev").unwrap();

    // the canonical §5.9 mixed workload: real lengths with every 4th row
    // at the model max — the same shape the e2e sweep's ≥2x assertion
    // runs on (one shared constructor, so the two cannot drift)
    let rows: Vec<(Vec<i32>, Vec<i32>)> = (0..24.min(split.len()))
        .map(|i| {
            let (ids, tys) = split.row(i);
            (ids.to_vec(), tys.to_vec())
        })
        .collect();
    let payload = zqhero::data::mixed_length_workload(&rows);
    let rxs: Vec<_> = payload
        .iter()
        .map(|(ids, tys)| {
            coord
                .submit(
                    RequestSpec::task("cola").mode("fp").ids(ids.clone()).type_ids(tys.clone()),
                )
                .expect("admitted")
        })
        .collect();
    let resps: Vec<Response> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(120)).expect("reply"))
        .collect();

    let mut rt = Runtime::new(Manifest::load(&dir).unwrap()).unwrap();
    let cola = rt.manifest.task("cola").unwrap().clone();
    eh::ensure_checkpoint(&mut rt, &cola, "fp", 4, 100.0).unwrap();
    let mut buckets_seen = std::collections::BTreeSet::new();
    for ((ids, tys), resp) in payload.iter().zip(&resps) {
        assert!(resp.error.is_none(), "{:?}", resp.error);
        // the batch rode the smallest manifest bucket fitting this request
        assert_eq!(
            resp.timing.seq_bucket,
            man.seq_bucket_for(ids.len()),
            "request of {} tokens",
            ids.len()
        );
        buckets_seen.insert(resp.timing.seq_bucket);
        // per-batch padding ledger coherence
        assert!(resp.timing.real_tokens >= ids.len());
        assert!(resp.timing.real_tokens <= resp.timing.padded_tokens);
        assert_eq!(
            resp.timing.padded_tokens,
            resp.timing.bucket * resp.timing.seq_bucket,
            "padded slots must be the staging cell"
        );
        assert_timing_coherent(resp, &format!("mixed-length len {}", ids.len()));
        // numeric parity vs direct single-row inference at the same cell
        let s = resp.timing.seq_bucket;
        let mut pids = ids.clone();
        pids.resize(s, 0);
        let mut ptys = tys.clone();
        ptys.resize(s, 0);
        let mask = Split::mask_row(&pids);
        let direct = rt.infer("cola", "fp", 1, &pids, &ptys, &mask).unwrap();
        let dv = direct.as_f32().unwrap();
        for (a, b) in resp.logits.iter().zip(dv) {
            assert!(
                (a - b).abs() < 1e-3,
                "len {}: coordinator {a} vs direct {b}",
                ids.len()
            );
        }
    }
    assert!(
        buckets_seen.len() > 1,
        "mixed workload must actually exercise multiple seq buckets, saw {buckets_seen:?}"
    );
    // FIFO within each class: responses of one class ride non-decreasing
    // dispatch numbers in submit order
    for sb in &buckets_seen {
        let class: Vec<Response> = resps
            .iter()
            .filter(|r| r.timing.seq_bucket == *sb)
            .cloned()
            .collect();
        assert_group_fifo(&class, 1, &format!("seq class {sb}"));
    }
}

#[test]
fn unknown_route_rejected_at_admission() {
    let Some(dir) = artifacts() else { return };
    let pairs = vec![("cola".to_string(), "fp".to_string())];
    let coord = Coordinator::start(dir, &pairs, config(true)).unwrap();
    let seq = coord.seq();
    // manifest-unknown task and known-but-unloaded mode both fail fast,
    // with an error that names the missing checkpoint
    for (task, mode) in [("nope", "fp"), ("cola", "m3")] {
        let err = coord
            .submit(RequestSpec::task(task).policy(mode).ids(vec![1; seq]).type_ids(vec![0; seq]))
            .unwrap_err();
        assert!(err.to_string().contains("checkpoint"), "{err}");
    }
}

#[test]
fn readback_stage_panic_is_isolated() {
    let Some(dir) = artifacts() else { return };
    let pairs = vec![("cola".to_string(), "fp".to_string())];
    let coord = Coordinator::start(
        dir.clone(),
        &pairs,
        ServerConfig { fault_plan: FaultPlan::completion_panic_at(0), ..config(true) },
    )
    .unwrap();

    let man = Manifest::load(&dir).unwrap();
    let split = Split::load(&man, man.task("cola").unwrap(), "dev").unwrap();
    let (ids, tys) = split.row(0);

    // batch 0's completion panics on the worker pool: its requests get a
    // hangup, never a wrong answer
    let rx = coord
        .submit(RequestSpec::task("cola").mode("fp").ids(ids.to_vec()).type_ids(tys.to_vec()))
        .unwrap();
    match rx.recv_timeout(Duration::from_secs(120)) {
        Err(_) => {} // reply sender dropped by the panicking completion
        Ok(resp) => panic!("poisoned batch must not reply, got {resp:?}"),
    }

    // the engine thread and worker pool survive: subsequent traffic flows
    for i in 0..10 {
        let (ids, tys) = split.row(i % split.len());
        let rx = coord
            .submit(RequestSpec::task("cola").mode("fp").ids(ids.to_vec()).type_ids(tys.to_vec()))
            .unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(resp.timing.batch_seq >= 1);
    }
}
