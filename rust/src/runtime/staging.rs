//! Pooled host staging buffers for batch assembly (DESIGN.md §5.3, §5.9).
//!
//! Every admitted batch needs three host arrays — `ids`, `type_ids`,
//! `mask`, each `[bucket * seq_bucket]` — that exist only long enough to
//! be copied into device buffers.  Allocating them per batch puts the
//! allocator on the steady-state path; instead the batcher thread checks
//! a `StagingBuf` out of a per-(seq bucket, batch bucket) shelf, fills it
//! in place, and the engine thread returns it to the shelf right after
//! the host→device upload.  Shelves are keyed by the same grid as the
//! executable tables, so a short batch stages `bucket * seq_bucket`
//! tokens — not `bucket * max_seq`.  Shelves are bounded so a burst
//! cannot pin unbounded memory: overflow buffers are simply dropped and
//! the shelf refills on demand.

use crate::data::PAD;
use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::{Mutex, MutexGuard};

/// One reusable host-side batch: `bucket * seq` token ids / type ids and
/// the derived attention mask.  `real` tracks how many rows were filled
/// before padding; `real_tokens` how many caller tokens those rows
/// carried before per-row padding (the padding-efficiency numerator).
#[derive(Debug)]
pub struct StagingBuf {
    pub bucket: usize,
    pub seq: usize,
    pub real: usize,
    pub real_tokens: usize,
    pub ids: Vec<i32>,
    pub type_ids: Vec<i32>,
    pub mask: Vec<f32>,
}

impl StagingBuf {
    pub fn new(bucket: usize, seq: usize) -> Self {
        StagingBuf {
            bucket,
            seq,
            real: 0,
            real_tokens: 0,
            ids: Vec::with_capacity(bucket * seq),
            type_ids: Vec::with_capacity(bucket * seq),
            mask: Vec::with_capacity(bucket * seq),
        }
    }

    /// Wrap caller-owned arrays (blocking/CLI path, no pool involved).
    /// `mask` is recomputed to keep one definition of padding semantics.
    /// `real` is the number of rows the caller actually provided
    /// (`ids.len() / seq`, rounded up for a partial final row, capped at
    /// the bucket) — hardcoding `real = bucket` overstated occupancy in
    /// blocking-path timings and `batch_real` reporting whenever fewer
    /// rows were passed.
    pub fn from_parts(bucket: usize, seq: usize, ids: Vec<i32>, type_ids: Vec<i32>) -> Self {
        let real = ids.len().div_ceil(seq.max(1)).min(bucket);
        let real_tokens = ids.len().min(bucket * seq);
        let mut buf = StagingBuf {
            bucket,
            seq,
            real,
            real_tokens,
            ids,
            type_ids,
            mask: Vec::new(),
        };
        buf.ids.resize(bucket * seq, PAD);
        buf.type_ids.resize(bucket * seq, 0);
        buf.mask = buf.ids.iter().map(|t| if *t == PAD { 0.0 } else { 1.0 }).collect();
        buf
    }

    /// Total token slots the device sees (`bucket * seq` — the
    /// padding-efficiency denominator).
    pub fn padded_tokens(&self) -> usize {
        self.bucket * self.seq
    }

    /// Clear contents, keeping capacity (called on checkout).
    fn reset(&mut self, bucket: usize, seq: usize) {
        self.bucket = bucket;
        self.seq = seq;
        self.real = 0;
        self.real_tokens = 0;
        self.ids.clear();
        self.type_ids.clear();
        self.mask.clear();
    }

    /// Append one request row of up to `seq` real tokens; the row is
    /// padded to the seq bucket in place (requests arrive unpadded —
    /// admission stopped padding to the model max, DESIGN.md §5.9).
    pub fn push_row(&mut self, ids: &[i32], type_ids: &[i32]) {
        debug_assert!(ids.len() <= self.seq, "row longer than seq bucket");
        debug_assert_eq!(type_ids.len(), ids.len());
        let row_end = self.ids.len() + self.seq;
        self.ids.extend_from_slice(ids);
        self.ids.resize(row_end, PAD);
        self.type_ids.extend_from_slice(type_ids);
        self.type_ids.resize(row_end, 0);
        self.real += 1;
        self.real_tokens += ids.len();
    }

    /// Pad to the bucket and derive the attention mask in one pass.
    pub fn finish(&mut self) {
        let n = self.bucket * self.seq;
        self.ids.resize(n, PAD);
        self.type_ids.resize(n, 0);
        self.mask.clear();
        self.mask.extend(self.ids.iter().map(|t| if *t == PAD { 0.0 } else { 1.0 }));
    }
}

/// Bounded free lists of `StagingBuf`s over the (seq bucket, batch
/// bucket) grid, shared between the batcher thread (checkout + fill) and
/// the engine thread (return after upload).  Lock scope is a `Vec`
/// push/pop — nanoseconds next to the memcpy the buffer exists for.
pub struct StagingPool {
    seq_buckets: Vec<usize>,
    buckets: Vec<usize>,
    /// Live cap — shrunk by `trim` when replicas are excluded, so a
    /// degraded pool doesn't keep shelving buffers sized for the full
    /// replica count.  Relaxed is enough: the cap is a soft bound read
    /// racily by `put`, never a synchronization edge.
    per_cell_cap: AtomicUsize,
    /// The cap the pool was built with (the `trim` scaling baseline).
    initial_cap: usize,
    /// `[seq_index * buckets.len() + bucket_index]` — one shelf per cell.
    shelves: Vec<Mutex<Vec<StagingBuf>>>,
}

impl StagingPool {
    pub fn new(seq_buckets: &[usize], buckets: &[usize], per_cell_cap: usize) -> Self {
        let cap = per_cell_cap.max(1);
        StagingPool {
            seq_buckets: seq_buckets.to_vec(),
            buckets: buckets.to_vec(),
            per_cell_cap: AtomicUsize::new(cap),
            initial_cap: cap,
            shelves: (0..seq_buckets.len() * buckets.len()).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    /// Scale the per-cell cap to the live replica share and drop shelved
    /// buffers beyond it (replica exclusion teardown: a pool sized for N
    /// replicas should not keep N replicas' worth of staging resident
    /// when only `live` remain).  Never drops below one buffer per cell.
    pub fn trim(&self, live: usize, total: usize) {
        let cap = if total == 0 {
            self.initial_cap
        } else {
            (self.initial_cap * live.min(total) / total).max(1)
        };
        // relaxed-ok: soft bound, see per_cell_cap
        self.per_cell_cap.store(cap, Ordering::Relaxed);
        for i in 0..self.shelves.len() {
            let mut shelf = self.shelf(i);
            shelf.truncate(cap);
        }
    }

    fn shelf_index(&self, seq: usize, bucket: usize) -> Option<usize> {
        let si = self.seq_buckets.iter().position(|s| *s == seq)?;
        let bi = self.buckets.iter().position(|b| *b == bucket)?;
        Some(si * self.buckets.len() + bi)
    }

    /// Lock one shelf, recovering from poisoning: a shelf is a plain
    /// free list, and the worst a panicking holder can leave behind is
    /// a buffer checked out or dropped — never torn state — so the pool
    /// keeps recycling instead of cascading the panic into the batcher
    /// and engine threads.
    fn shelf(&self, i: usize) -> MutexGuard<'_, Vec<StagingBuf>> {
        match self.shelves[i].lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Check out a cleared buffer for the (seq, bucket) cell, reusing
    /// capacity when a recycled one is on the shelf.
    pub fn take(&self, seq: usize, bucket: usize) -> StagingBuf {
        if let Some(i) = self.shelf_index(seq, bucket) {
            if let Some(mut buf) = self.shelf(i).pop() {
                buf.reset(bucket, seq);
                return buf;
            }
        }
        StagingBuf::new(bucket, seq)
    }

    /// Return a buffer after upload; dropped silently when the shelf is
    /// full or the cell is foreign (blocking-path buffers).
    pub fn put(&self, buf: StagingBuf) {
        if let Some(i) = self.shelf_index(buf.seq, buf.bucket) {
            let mut shelf = self.shelf(i);
            // relaxed-ok: soft bound, see per_cell_cap
            if shelf.len() < self.per_cell_cap.load(Ordering::Relaxed) {
                shelf.push(buf);
            }
        }
    }

    /// Buffers currently resting on shelves (tests / introspection).
    pub fn pooled(&self) -> usize {
        (0..self.shelves.len()).map(|i| self.shelf(i).len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_pads_and_masks() {
        let mut b = StagingBuf::new(2, 4);
        b.push_row(&[5, 6, 0, 0], &[0, 0, 0, 0]);
        b.finish();
        assert_eq!(b.real, 1);
        assert_eq!(b.ids, vec![5, 6, 0, 0, 0, 0, 0, 0]);
        assert_eq!(b.type_ids.len(), 8);
        assert_eq!(b.mask, vec![1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn short_rows_pad_to_the_seq_bucket() {
        // unpadded admission: rows shorter than the seq bucket pad in
        // place, and real_tokens counts only what the caller provided
        let mut b = StagingBuf::new(2, 4);
        b.push_row(&[7, 8], &[0, 1]);
        b.push_row(&[9], &[0]);
        b.finish();
        assert_eq!(b.real, 2);
        assert_eq!(b.real_tokens, 3);
        assert_eq!(b.padded_tokens(), 8);
        assert_eq!(b.ids, vec![7, 8, 0, 0, 9, 0, 0, 0]);
        assert_eq!(b.type_ids, vec![0, 1, 0, 0, 0, 0, 0, 0]);
        assert_eq!(b.mask, vec![1.0, 1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn pool_recycles_capacity() {
        let pool = StagingPool::new(&[4], &[1, 4], 2);
        let mut a = pool.take(4, 4);
        a.push_row(&[1, 2, 3, 4], &[0; 4]);
        a.finish();
        let cap_before = a.ids.capacity();
        pool.put(a);
        assert_eq!(pool.pooled(), 1);
        let b = pool.take(4, 4);
        assert_eq!(pool.pooled(), 0);
        assert_eq!(b.real, 0);
        assert_eq!(b.real_tokens, 0);
        assert!(b.ids.is_empty());
        assert!(b.ids.capacity() >= cap_before.min(16));
    }

    #[test]
    fn pool_keys_cells_by_seq_and_batch() {
        // the grid keeps per-cell shelves apart: a (seq 2, bucket 2)
        // buffer never satisfies a (seq 4, bucket 2) checkout
        let pool = StagingPool::new(&[2, 4], &[2], 1);
        pool.put(StagingBuf::new(2, 2));
        assert_eq!(pool.pooled(), 1);
        let b = pool.take(4, 2);
        assert_eq!((b.seq, b.bucket), (4, 2));
        assert_eq!(pool.pooled(), 1, "the seq-2 shelf is untouched");
        let b2 = pool.take(2, 2);
        assert_eq!((b2.seq, b2.bucket), (2, 2));
        assert_eq!(pool.pooled(), 0, "the shelved seq-2 buffer was recycled");
    }

    #[test]
    fn pool_bounds_and_tolerates_foreign_cells() {
        let pool = StagingPool::new(&[2], &[2], 1);
        pool.put(StagingBuf::new(2, 2));
        pool.put(StagingBuf::new(2, 2)); // over cap: dropped
        assert_eq!(pool.pooled(), 1);
        pool.put(StagingBuf::new(7, 2)); // unknown bucket: dropped
        pool.put(StagingBuf::new(2, 9)); // unknown seq: dropped
        assert_eq!(pool.pooled(), 1);
    }

    #[test]
    fn trim_scales_cap_to_live_share_and_drops_excess() {
        let pool = StagingPool::new(&[2], &[2], 4);
        for _ in 0..4 {
            pool.put(StagingBuf::new(2, 2));
        }
        assert_eq!(pool.pooled(), 4);
        // half the replicas are gone: cap halves and shelves shed
        pool.trim(2, 4);
        assert_eq!(pool.pooled(), 2);
        pool.put(StagingBuf::new(2, 2)); // over the trimmed cap: dropped
        assert_eq!(pool.pooled(), 2);
        // the floor is one buffer per cell even with zero live replicas
        pool.trim(0, 4);
        assert_eq!(pool.pooled(), 1);
        // recovery restores the full share
        pool.trim(4, 4);
        for _ in 0..4 {
            pool.put(StagingBuf::new(2, 2));
        }
        assert_eq!(pool.pooled(), 4);
    }

    #[test]
    fn from_parts_matches_fill_semantics() {
        let b = StagingBuf::from_parts(2, 3, vec![9, 0, 9], vec![1, 1, 1]);
        assert_eq!(b.ids, vec![9, 0, 9, 0, 0, 0]);
        assert_eq!(b.mask, vec![1.0, 0.0, 1.0, 0.0, 0.0, 0.0]);
        // one row of tokens was provided: real reports 1, not the bucket
        assert_eq!(b.real, 1);
        assert_eq!(b.real_tokens, 3);
    }

    #[test]
    fn from_parts_derives_real_from_rows_provided() {
        // full bucket: unchanged semantics
        let b = StagingBuf::from_parts(2, 3, vec![1; 6], vec![0; 6]);
        assert_eq!(b.real, 2);
        assert_eq!(b.real_tokens, 6);
        // partial final row rounds up, and real never exceeds the bucket
        let b = StagingBuf::from_parts(4, 3, vec![1; 4], vec![0; 4]);
        assert_eq!(b.real, 2);
        let b = StagingBuf::from_parts(2, 3, vec![1; 9], vec![0; 9]);
        assert_eq!(b.real, 2);
        assert_eq!(b.real_tokens, 6, "token count capped at the buffer size");
        // degenerate inputs stay safe
        let b = StagingBuf::from_parts(2, 0, vec![], vec![]);
        assert_eq!(b.real, 0);
        assert_eq!(b.real_tokens, 0);
    }
}
