//! End-to-end coordinator test: requests through admission -> batcher ->
//! engine -> completion, with correct per-request row mapping.
//! Gated on `make artifacts`.

use std::path::{Path, PathBuf};
use std::time::Duration;

use zqhero::coordinator::{Coordinator, ServerConfig};
use zqhero::data::Split;
use zqhero::model::manifest::Manifest;
use zqhero::model::Container;
use zqhero::runtime::Runtime;

fn artifacts() -> Option<PathBuf> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p.to_path_buf())
    } else {
        eprintln!("skipping coordinator tests: run `make artifacts` first");
        None
    }
}

#[test]
fn serve_fp_requests_end_to_end() {
    let Some(dir) = artifacts() else { return };
    let pairs = vec![("cola".to_string(), "fp".to_string())];
    let coord = Coordinator::start(
        dir.clone(),
        &pairs,
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            ..Default::default()
        },
    )
    .unwrap();

    let man = Manifest::load(&dir).unwrap();
    let task = man.task("cola").unwrap();
    let split = Split::load(&man, task, "dev").unwrap();
    let n = 40.min(split.len());

    // submit everything, then collect
    let mut rxs = Vec::new();
    for i in 0..n {
        let (ids, tys) = split.row(i);
        let rx = coord
            .submit("cola", "fp", ids.to_vec(), tys.to_vec())
            .unwrap();
        rxs.push(rx);
    }
    let mut responses = Vec::new();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.logits.len(), coord.num_labels());
        assert!(resp.logits.iter().all(|x| x.is_finite()));
        assert!(resp.timing.batch_real >= 1 && resp.timing.batch_real <= 8);
        assert!(resp.timing.bucket >= resp.timing.batch_real);
        responses.push(resp);
    }

    // row mapping: responses must equal direct runtime inference per example
    let mut rt = Runtime::new(Manifest::load(&dir).unwrap()).unwrap();
    let fp = Container::read_file(&rt.manifest.path(&task.checkpoint))
        .unwrap()
        .reordered(&rt.manifest.mode("fp").unwrap().params)
        .unwrap();
    rt.upload_checkpoint("cola", "fp", &fp).unwrap();
    for (i, resp) in responses.iter().enumerate().take(10) {
        let (ids, tys) = split.row(i);
        let mask = Split::mask_row(ids);
        let direct = rt.infer("cola", "fp", 1, ids, tys, &mask).unwrap();
        let dv = direct.as_f32().unwrap();
        for (a, b) in resp.logits.iter().zip(dv) {
            assert!(
                (a - b).abs() < 1e-3,
                "request {i}: coordinator {a} vs direct {b}"
            );
        }
    }

    // metrics recorded
    let snap = coord.recorder.snapshot();
    assert_eq!(snap["fp"].requests, n as u64);
    assert_eq!(snap["fp"].errors, 0);
    assert!(snap["fp"].batches >= (n / 8) as u64);
}

#[test]
fn rejects_malformed_and_applies_backpressure_shape() {
    let Some(dir) = artifacts() else { return };
    let pairs = vec![("cola".to_string(), "fp".to_string())];
    let coord = Coordinator::start(
        dir,
        &pairs,
        ServerConfig { queue_cap: 4, ..Default::default() },
    )
    .unwrap();
    // wrong seq length is rejected before admission
    assert!(coord.submit("cola", "fp", vec![1, 2, 3], vec![0, 0, 0]).is_err());
}

#[test]
fn unknown_checkpoint_fails_at_startup() {
    let Some(dir) = artifacts() else { return };
    let pairs = vec![("cola".to_string(), "m9".to_string())];
    assert!(Coordinator::start(dir, &pairs, ServerConfig::default()).is_err());
}
