//! Table 1: quantization-mode matrix — printed from the manifest and
//! *verified* against the lowered artifacts (each mode's HLO must contain
//! exactly the int8 GeMMs its Table-1 row claims).

use zqhero::bench::Table;
use zqhero::model::manifest::Manifest;
use zqhero::traceflow;

fn main() {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("table1_modes: run `make artifacts` first");
        return;
    }
    let man = Manifest::load(&dir).expect("manifest");

    println!("\nTable 1: quantization modes of ZeroQuant-HERO");
    println!("(check = INT8, x = FP16/BF16 — FP32 on this CPU testbed)\n");
    let mut t = Table::new(&[
        "Mode", "Embedding", "QKV GeMM", "Attn.", "Attn. Output", "FC1", "FC2",
    ]);
    let mark = |b: bool| if b { "v".to_string() } else { "x".to_string() };
    for name in &man.mode_order {
        if name == "fp" {
            continue;
        }
        let r = man.modes[name].switches.row();
        t.row(vec![
            format!("ZeroQuant-HERO-{}", name.to_uppercase()),
            mark(r[0]), mark(r[1]), mark(r[2]), mark(r[3]), mark(r[4]), mark(r[5]),
        ]);
    }
    t.print();

    println!("\nartifact verification (int8 GeMM count per lowered HLO):");
    let mut v = Table::new(&["mode", "bucket", "expected", "found", "ok"]);
    let mut all_ok = true;
    for name in &man.mode_order {
        for bucket in &man.buckets {
            let (expected, found) =
                traceflow::verify_mode_artifact(&man, name, *bucket).expect("verify");
            let ok = expected == found;
            all_ok &= ok;
            v.row(vec![
                name.clone(),
                format!("b{bucket}"),
                expected.to_string(),
                found.to_string(),
                if ok { "OK" } else { "MISMATCH" }.to_string(),
            ]);
        }
    }
    v.print();
    assert!(all_ok, "artifacts do not match Table 1 claims");
    println!("\nall artifacts match their Table 1 rows");
}
