//! The four herolint analyses (DESIGN.md §5.11), run over the facts
//! extracted by [`super::facts`].
//!
//! 1. **lock-order** — build the inter-procedural lock graph (nested
//!    acquisitions plus calls made while holding a guard, resolved to
//!    lock-acquiring functions by unique bare name) and fail on any
//!    strongly-connected component: a cycle is a potential deadlock.
//! 2. **atomic-ordering** — flag `Ordering::Relaxed` sites that look
//!    like cross-thread handshakes: the field is accessed with mixed
//!    orderings, the function participates in a Condvar protocol, or
//!    the field is Relaxed-stored in one function and Relaxed-loaded in
//!    another (publish/observe pair).  `// relaxed-ok: <reason>`
//!    suppresses.
//! 3. **panic-path** — forbid `unwrap()`/`expect()`/arithmetic slice
//!    indexing in serving modules (`coordinator/*`, `runtime/*`,
//!    `exec/*`) without `// panic-ok: <invariant>`.
//! 4. **ledger-identity** — every counter in the reconciliation
//!    identity `requests == completed + errors + expired + failed`
//!    must have exactly one owning `Recorder` method, that method must
//!    also bump `requests`, and every production call site of it must
//!    be a terminal-reply path (a function that sends a wire reply).
//! 5. **hold-across-blocking** — a lock guard live across a call that
//!    can park the thread (`send`/`recv`/`join`/`sleep`/file or socket
//!    IO) in serving modules stalls every peer of that lock for the
//!    duration of the park.  `// block-ok: <reason>` suppresses; a
//!    condvar `wait` only counts when a second guard rides along (the
//!    waited guard itself is released by the condvar).

use std::collections::{BTreeMap, BTreeSet, HashMap};

use super::facts::{extract, FnFacts};
use super::lexer::lex;

pub const RULE_LOCK_ORDER: &str = "lock-order";
pub const RULE_ATOMIC: &str = "atomic-ordering";
pub const RULE_PANIC: &str = "panic-path";
pub const RULE_LEDGER: &str = "ledger-identity";
pub const RULE_HOLD_BLOCKING: &str = "hold-across-blocking";

/// Counters on the right-hand side of the reconciliation identity.
const IDENTITY_RHS: [&str; 4] = ["completed", "errors", "expired", "failed"];

#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub message: String,
}

/// One edge of the lock graph, with its witness site.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct LockEdge {
    pub from: String,
    pub to: String,
    pub file: String,
    pub line: u32,
    /// `Some(callee)` for inter-procedural edges.
    pub via: Option<String>,
}

#[derive(Debug, Default)]
pub struct Analysis {
    pub findings: Vec<Finding>,
    pub edges: Vec<LockEdge>,
    pub files: usize,
    pub functions: usize,
    pub suppressed_panic: usize,
    pub suppressed_relaxed: usize,
    pub suppressed_block: usize,
}

/// Run all four analyses over `(relative_path, source)` pairs.
pub fn analyze(files: &[(String, String)]) -> Analysis {
    // Pass 1: lex + extract with no helper knowledge, to learn which
    // functions hand out guards (poison-recovery helpers).
    let lexed: Vec<_> = files.iter().map(|(p, s)| (p.clone(), lex(s))).collect();
    let mut first: Vec<FnFacts> = Vec::new();
    for (p, lx) in &lexed {
        first.extend(extract(p, lx, &HashMap::new()));
    }
    let mut helpers: HashMap<String, String> = HashMap::new();
    let mut ambiguous: BTreeSet<String> = BTreeSet::new();
    for f in &first {
        if f.guard_helper {
            if let Some(a) = f.acquires.first() {
                match helpers.get(&f.name) {
                    Some(c) if *c != a.class => {
                        ambiguous.insert(f.name.clone());
                    }
                    _ => {
                        helpers.insert(f.name.clone(), a.class.clone());
                    }
                }
            }
        }
    }
    for name in &ambiguous {
        helpers.remove(name);
    }

    // Pass 2: the real extraction — helper calls now count as
    // acquisitions at the call site.
    let mut fns: Vec<FnFacts> = Vec::new();
    for (p, lx) in &lexed {
        fns.extend(extract(p, lx, &helpers));
    }

    let mut a = Analysis {
        files: files.len(),
        functions: fns.len(),
        ..Analysis::default()
    };
    a.suppressed_panic = fns.iter().flat_map(|f| &f.panics).filter(|p| p.suppressed).count();
    a.suppressed_relaxed = fns
        .iter()
        .flat_map(|f| &f.atomics)
        .filter(|s| s.ordering == "Relaxed" && s.suppressed)
        .count();
    a.suppressed_block = fns.iter().flat_map(|f| &f.blocking).filter(|b| b.suppressed).count();

    lock_order(&fns, &mut a);
    atomic_ordering(&fns, &mut a);
    panic_path(&fns, &mut a);
    ledger_identity(&fns, &mut a);
    hold_blocking(&fns, &mut a);

    a.findings.sort_by(|x, y| {
        (x.rule, &x.file, x.line).cmp(&(y.rule, &y.file, y.line))
    });
    a
}

// ---------------------------------------------------------------- rule 1

fn lock_order(fns: &[FnFacts], a: &mut Analysis) {
    // Transitive lock sets per function, grown to a fixpoint through
    // calls that resolve uniquely (by bare name, self excluded) to a
    // lock-acquiring function.
    let mut by_name: HashMap<&str, Vec<usize>> = HashMap::new();
    for (i, f) in fns.iter().enumerate() {
        by_name.entry(f.name.as_str()).or_default().push(i);
    }
    let mut trans: Vec<BTreeSet<String>> = fns
        .iter()
        .map(|f| f.acquires.iter().map(|q| q.class.clone()).collect())
        .collect();
    loop {
        let mut changed = false;
        for i in 0..fns.len() {
            for (callee, _) in &fns[i].calls {
                if let Some(j) = resolve(callee, i, &by_name, &trans) {
                    let add: Vec<String> =
                        trans[j].iter().filter(|c| !trans[i].contains(*c)).cloned().collect();
                    if !add.is_empty() {
                        trans[i].extend(add);
                        changed = true;
                    }
                }
            }
        }
        if !changed {
            break;
        }
    }

    // Edges: intra-procedural nesting + held-across-call expansion.
    let mut edges: BTreeSet<LockEdge> = BTreeSet::new();
    for (i, f) in fns.iter().enumerate() {
        for nst in &f.nested {
            edges.insert(LockEdge {
                from: nst.held.clone(),
                to: nst.class.clone(),
                file: f.file.clone(),
                line: nst.line,
                via: None,
            });
        }
        for lc in &f.locked_calls {
            if let Some(j) = resolve(&lc.callee, i, &by_name, &trans) {
                for h in &lc.held {
                    for c in &trans[j] {
                        edges.insert(LockEdge {
                            from: h.clone(),
                            to: c.clone(),
                            file: f.file.clone(),
                            line: lc.line,
                            via: Some(lc.callee.clone()),
                        });
                    }
                }
            }
        }
    }
    // A `from == to` edge only counts when it is a *direct* nested
    // re-acquisition (via: None): call-resolution is name-based and
    // over-approximate, so `x.push(…)` under a guard must not convict
    // the guard of re-entering itself.
    let edges: Vec<LockEdge> =
        edges.into_iter().filter(|e| e.from != e.to || e.via.is_none()).collect();

    // SCCs over the class graph; any SCC with a cycle is a finding.
    let mut classes: Vec<&str> = Vec::new();
    let mut class_ix: BTreeMap<&str, usize> = BTreeMap::new();
    for e in &edges {
        for c in [e.from.as_str(), e.to.as_str()] {
            if !class_ix.contains_key(c) {
                class_ix.insert(c, classes.len());
                classes.push(c);
            }
        }
    }
    let mut adj = vec![Vec::new(); classes.len()];
    for e in &edges {
        adj[class_ix[e.from.as_str()]].push(class_ix[e.to.as_str()]);
    }
    for scc in sccs(&adj) {
        let cyclic = scc.len() > 1
            || adj[scc[0]].contains(&scc[0]);
        if !cyclic {
            continue;
        }
        let members: BTreeSet<&str> = scc.iter().map(|&i| classes[i]).collect();
        let mut witness: Vec<String> = edges
            .iter()
            .filter(|e| members.contains(e.from.as_str()) && members.contains(e.to.as_str()))
            .map(|e| {
                let via =
                    e.via.as_ref().map(|v| format!(" via {}()", v)).unwrap_or_default();
                format!("`{}` -> `{}` at {}:{}{}", e.from, e.to, e.file, e.line, via)
            })
            .collect();
        witness.dedup();
        let (file, line) = edges
            .iter()
            .find(|e| members.contains(e.from.as_str()) && members.contains(e.to.as_str()))
            .map(|e| (e.file.clone(), e.line))
            .unwrap_or_default();
        let names: Vec<String> = members.iter().map(|m| format!("`{}`", m)).collect();
        a.findings.push(Finding {
            rule: RULE_LOCK_ORDER,
            file,
            line,
            message: format!(
                "lock-order cycle among {{{}}} — potential deadlock; witness edges: {}",
                names.join(", "),
                witness.join("; ")
            ),
        });
    }
    a.edges = edges;
}

/// Resolve a bare callee name to the unique lock-acquiring function
/// with that name (excluding `except`, normally the caller).
fn resolve(
    callee: &str,
    except: usize,
    by_name: &HashMap<&str, Vec<usize>>,
    trans: &[BTreeSet<String>],
) -> Option<usize> {
    let cands: Vec<usize> = by_name
        .get(callee)?
        .iter()
        .copied()
        .filter(|&j| j != except && !trans[j].is_empty())
        .collect();
    if cands.len() == 1 {
        Some(cands[0])
    } else {
        None
    }
}

/// Tarjan's strongly-connected components (recursive; lock graphs are
/// a handful of nodes).
fn sccs(adj: &[Vec<usize>]) -> Vec<Vec<usize>> {
    struct St<'a> {
        adj: &'a [Vec<usize>],
        index: Vec<Option<u32>>,
        low: Vec<u32>,
        on: Vec<bool>,
        stack: Vec<usize>,
        next: u32,
        out: Vec<Vec<usize>>,
    }
    fn dfs(st: &mut St, v: usize) {
        st.index[v] = Some(st.next);
        st.low[v] = st.next;
        st.next += 1;
        st.stack.push(v);
        st.on[v] = true;
        let ns = st.adj[v].clone();
        for w in ns {
            if st.index[w].is_none() {
                dfs(st, w);
                st.low[v] = st.low[v].min(st.low[w]);
            } else if st.on[w] {
                // panic-ok: index[w] was just checked Some
                st.low[v] = st.low[v].min(st.index[w].unwrap());
            }
        }
        // panic-ok: index[v] assigned at entry
        if st.low[v] == st.index[v].unwrap() {
            let mut comp = Vec::new();
            loop {
                // panic-ok: v is still on the stack by the SCC invariant
                let w = st.stack.pop().unwrap();
                st.on[w] = false;
                comp.push(w);
                if w == v {
                    break;
                }
            }
            st.out.push(comp);
        }
    }
    let n = adj.len();
    let mut st = St {
        adj,
        index: vec![None; n],
        low: vec![0; n],
        on: vec![false; n],
        stack: Vec::new(),
        next: 0,
        out: Vec::new(),
    };
    for v in 0..n {
        if st.index[v].is_none() {
            dfs(&mut st, v);
        }
    }
    st.out
}

// ---------------------------------------------------------------- rule 2

fn atomic_ordering(fns: &[FnFacts], a: &mut Analysis) {
    // field -> the set of orderings it is accessed with, anywhere
    let mut orderings: HashMap<&str, BTreeSet<&str>> = HashMap::new();
    // field -> (functions that Relaxed-store it, functions that Relaxed-load it)
    let mut stores: HashMap<&str, BTreeSet<&str>> = HashMap::new();
    let mut loads: HashMap<&str, BTreeSet<&str>> = HashMap::new();
    for f in fns {
        for s in &f.atomics {
            orderings.entry(&s.field).or_default().insert(&s.ordering);
            if s.ordering == "Relaxed" {
                if s.is_store {
                    stores.entry(&s.field).or_default().insert(&f.qual);
                } else {
                    loads.entry(&s.field).or_default().insert(&f.qual);
                }
            }
        }
    }
    for f in fns {
        for s in &f.atomics {
            if s.ordering != "Relaxed" || s.suppressed {
                continue;
            }
            let mut reasons: Vec<String> = Vec::new();
            let ords = &orderings[s.field.as_str()];
            if ords.len() > 1 {
                let others: Vec<&str> =
                    ords.iter().copied().filter(|o| *o != "Relaxed").collect();
                reasons.push(format!(
                    "field `{}` is also accessed with {}",
                    s.field,
                    others.join("/")
                ));
            }
            if f.uses_condvar {
                reasons.push(format!(
                    "`{}` participates in a Condvar protocol",
                    f.qual
                ));
            }
            let st = stores.get(s.field.as_str());
            let ld = loads.get(s.field.as_str());
            if let (Some(st), Some(ld)) = (st, ld) {
                let cross = st.union(ld).count() >= 2;
                if cross {
                    reasons.push(format!(
                        "`{}` is Relaxed-published in {} and Relaxed-observed in {} — a cross-thread handshake",
                        s.field,
                        join_quoted(st),
                        join_quoted(ld)
                    ));
                }
            }
            if !reasons.is_empty() {
                a.findings.push(Finding {
                    rule: RULE_ATOMIC,
                    file: f.file.clone(),
                    line: s.line,
                    message: format!(
                        "Ordering::Relaxed on `{}.{}()` needs `// relaxed-ok: <reason>` or a stronger ordering: {}",
                        s.field,
                        s.method,
                        reasons.join("; ")
                    ),
                });
            }
        }
    }
}

fn join_quoted(s: &BTreeSet<&str>) -> String {
    let v: Vec<String> = s.iter().map(|x| format!("`{}`", x)).collect();
    v.join(", ")
}

// ---------------------------------------------------------------- rule 3

fn serving_path(file: &str) -> bool {
    file.starts_with("coordinator/") || file.starts_with("runtime/") || file.starts_with("exec/")
}

fn panic_path(fns: &[FnFacts], a: &mut Analysis) {
    for f in fns {
        if !serving_path(&f.file) {
            continue;
        }
        for p in &f.panics {
            if p.suppressed {
                continue;
            }
            a.findings.push(Finding {
                rule: RULE_PANIC,
                file: f.file.clone(),
                line: p.line,
                message: format!(
                    "{} in serving path (`{}`) — return an error, recover, or justify with `// panic-ok: <invariant>`",
                    p.kind.label(),
                    f.qual
                ),
            });
        }
    }
}

// ---------------------------------------------------------------- rule 5

fn hold_blocking(fns: &[FnFacts], a: &mut Analysis) {
    for f in fns {
        if !serving_path(&f.file) {
            continue;
        }
        for b in &f.blocking {
            if b.suppressed {
                continue;
            }
            let held: Vec<String> = b.held.iter().map(|h| format!("`{}`", h)).collect();
            a.findings.push(Finding {
                rule: RULE_HOLD_BLOCKING,
                file: f.file.clone(),
                line: b.line,
                message: format!(
                    "`{}()` can park `{}` while holding {} — every peer of that lock stalls for the duration; drop the guard first or justify with `// block-ok: <reason>`",
                    b.callee,
                    f.qual,
                    held.join(" + "),
                ),
            });
        }
    }
}

// ---------------------------------------------------------------- rule 4

fn ledger_identity(fns: &[FnFacts], a: &mut Analysis) {
    let recorder =
        |f: &FnFacts| f.impl_type.as_deref() == Some("Recorder");
    for counter in IDENTITY_RHS {
        let owners: Vec<&FnFacts> = fns
            .iter()
            .filter(|f| recorder(f) && f.increments.iter().any(|(c, _)| c == counter))
            .collect();
        match owners.len() {
            0 => {
                a.findings.push(Finding {
                    rule: RULE_LEDGER,
                    file: String::new(),
                    line: 0,
                    message: format!(
                        "identity counter `{}` has no Recorder increment site — the ledger cannot reconcile",
                        counter
                    ),
                });
                continue;
            }
            1 => {}
            _ => {
                let names: Vec<String> =
                    owners.iter().map(|f| format!("`{}`", f.qual)).collect();
                a.findings.push(Finding {
                    rule: RULE_LEDGER,
                    file: owners[1].file.clone(),
                    line: owners[1].line,
                    message: format!(
                        "identity counter `{}` is incremented by multiple Recorder methods ({}) — single-owner discipline broken",
                        counter,
                        names.join(", ")
                    ),
                });
            }
        }
        for owner in &owners {
            if !owner.increments.iter().any(|(c, _)| c == "requests") {
                a.findings.push(Finding {
                    rule: RULE_LEDGER,
                    file: owner.file.clone(),
                    line: owner.line,
                    message: format!(
                        "`{}` increments `{}` without `requests` — breaks `requests == completed + errors + expired + failed`",
                        owner.qual, counter
                    ),
                });
            }
            let callers: Vec<&FnFacts> = fns
                .iter()
                .filter(|f| !recorder(f) && f.calls.iter().any(|(c, _)| c == &owner.name))
                .collect();
            if callers.is_empty() {
                a.findings.push(Finding {
                    rule: RULE_LEDGER,
                    file: owner.file.clone(),
                    line: owner.line,
                    message: format!(
                        "`{}` (owner of `{}`) has no production call site — counter can never move",
                        owner.qual, counter
                    ),
                });
            }
            for caller in callers {
                if !caller.sends_reply {
                    a.findings.push(Finding {
                        rule: RULE_LEDGER,
                        file: caller.file.clone(),
                        line: caller.line,
                        message: format!(
                            "`{}` calls `{}` (counter `{}`) but is not a terminal-reply path — ledger increments must pair with exactly one reply",
                            caller.qual, owner.name, counter
                        ),
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(files: &[(&str, &str)]) -> Analysis {
        let owned: Vec<(String, String)> =
            files.iter().map(|(p, s)| (p.to_string(), s.to_string())).collect();
        analyze(&owned)
    }

    fn rules_of(a: &Analysis) -> Vec<&'static str> {
        a.findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn lock_cycle_detected_intra_procedurally() {
        let src = r#"
impl P {
    fn ab(&self) {
        let a = self.a.lock().expect("lock A");
        let b = self.b.lock().expect("lock B");
    }
    fn ba(&self) {
        let b = self.b.lock().expect("lock B");
        let a = self.a.lock().expect("lock A");
    }
}
"#;
        let a = run(&[("quant/demo.rs", src)]);
        let cyc: Vec<&Finding> =
            a.findings.iter().filter(|f| f.rule == RULE_LOCK_ORDER).collect();
        assert_eq!(cyc.len(), 1, "exactly one cycle finding: {:?}", a.findings);
        assert!(cyc[0].message.contains("lock A"));
        assert!(cyc[0].message.contains("lock B"));
    }

    #[test]
    fn lock_cycle_detected_through_calls() {
        let src = r#"
impl P {
    fn take_b_locked(&self) {
        let b = self.b.lock().expect("lock B");
    }
    fn take_a_locked(&self) {
        let a = self.a.lock().expect("lock A");
    }
    fn ab(&self) {
        let a = self.a.lock().expect("lock A");
        self.take_b_locked();
    }
    fn ba(&self) {
        let b = self.b.lock().expect("lock B");
        self.take_a_locked();
    }
}
"#;
        let a = run(&[("quant/demo.rs", src)]);
        assert!(
            rules_of(&a).contains(&RULE_LOCK_ORDER),
            "inter-procedural cycle must be found: {:?}",
            a.findings
        );
    }

    #[test]
    fn consistent_order_is_clean() {
        let src = r#"
impl P {
    fn one(&self) {
        let a = self.a.lock().expect("lock A");
        let b = self.b.lock().expect("lock B");
    }
    fn two(&self) {
        let a = self.a.lock().expect("lock A");
        let b = self.b.lock().expect("lock B");
    }
}
"#;
        let a = run(&[("quant/demo.rs", src)]);
        assert!(!rules_of(&a).contains(&RULE_LOCK_ORDER), "{:?}", a.findings);
        assert!(!a.edges.is_empty(), "the consistent edge must still be reported");
    }

    #[test]
    fn relaxed_handshake_flagged_and_suppressible() {
        let flagged = r#"
impl G {
    fn publish(&self) { self.level.store(1, Ordering::Relaxed); }
    fn observe(&self) -> u16 { self.level.load(Ordering::Relaxed) }
}
"#;
        let a = run(&[("quant/demo.rs", flagged)]);
        assert_eq!(
            rules_of(&a).iter().filter(|r| **r == RULE_ATOMIC).count(),
            2,
            "both ends of the handshake flag: {:?}",
            a.findings
        );

        let suppressed = r#"
impl G {
    fn publish(&self) {
        // relaxed-ok: single-cell value, no dependent data
        self.level.store(1, Ordering::Relaxed);
    }
    fn observe(&self) -> u16 {
        // relaxed-ok: single-cell value, no dependent data
        self.level.load(Ordering::Relaxed)
    }
}
"#;
        let a = run(&[("quant/demo.rs", suppressed)]);
        assert!(!rules_of(&a).contains(&RULE_ATOMIC), "{:?}", a.findings);
        assert_eq!(a.suppressed_relaxed, 2);
    }

    #[test]
    fn condvar_adjacent_relaxed_flagged_but_private_counter_clean() {
        let src = r#"
impl W {
    fn pump(&self) {
        self.ticks.fetch_add(1, Ordering::Relaxed);
        let g = self.cv.wait(self.m.lock().expect("pump lock")).unwrap();
    }
    fn alloc(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }
}
"#;
        let a = run(&[("quant/demo.rs", src)]);
        let atomic: Vec<&Finding> =
            a.findings.iter().filter(|f| f.rule == RULE_ATOMIC).collect();
        assert_eq!(atomic.len(), 1, "{:?}", a.findings);
        assert!(atomic[0].message.contains("Condvar"));
    }

    #[test]
    fn serving_path_panics_flagged_non_serving_clean() {
        let src = r#"
fn hot(&self) -> u32 {
    let v = self.m.get(&k).unwrap();
    self.tbl[i - 1]
}
"#;
        let a = run(&[("coordinator/demo.rs", src)]);
        assert_eq!(
            rules_of(&a).iter().filter(|r| **r == RULE_PANIC).count(),
            2,
            "unwrap + arithmetic index: {:?}",
            a.findings
        );
        let a = run(&[("quant/demo.rs", src)]);
        assert!(!rules_of(&a).contains(&RULE_PANIC), "{:?}", a.findings);
    }

    #[test]
    fn panic_ok_annotation_suppresses() {
        let src = "impl S {\n    fn hot(&self) {\n        // panic-ok: key inserted at construction\n        let v = self.m.get(&k).unwrap();\n    }\n}\n";
        let a = run(&[("runtime/demo.rs", src)]);
        assert!(!rules_of(&a).contains(&RULE_PANIC), "{:?}", a.findings);
        assert_eq!(a.suppressed_panic, 1);
    }

    fn ledger_base(record_done: &str, caller: &str) -> Analysis {
        let recorder = format!(
            r#"
impl Recorder {{
    {}
    fn record_errors(&self, s: &mut S) {{ s.requests += 1; s.errors += 1; }}
    fn record_expired(&self, s: &mut S) {{ s.requests += 1; s.expired += 1; }}
    fn record_failed(&self, s: &mut S) {{ s.requests += 1; s.failed += 1; }}
}}
"#,
            record_done
        );
        let server = format!(
            r#"
impl Server {{
    {}
    fn send_error(&self, r: R) {{ self.rec.record_errors(s); r.reply.send(e); }}
    fn send_expired(&self, r: R) {{ self.rec.record_expired(s); r.reply.send(e); }}
    fn send_failed(&self, r: R) {{ self.rec.record_failed(s); r.reply.send(e); }}
}}
"#,
            caller
        );
        run(&[
            ("coordinator/stats.rs", recorder.as_str()),
            ("coordinator/server.rs", server.as_str()),
        ])
    }

    #[test]
    fn healthy_ledger_is_clean() {
        let a = ledger_base(
            "fn record_done(&self, s: &mut S) { s.requests += 1; s.completed += 1; }",
            "fn dispatch(&self, r: R) { self.rec.record_done(s); r.reply.send(m); }",
        );
        assert!(!rules_of(&a).contains(&RULE_LEDGER), "{:?}", a.findings);
    }

    #[test]
    fn identity_breaking_increment_flagged() {
        let a = ledger_base(
            "fn record_done(&self, s: &mut S) { s.completed += 1; }",
            "fn dispatch(&self, r: R) { self.rec.record_done(s); r.reply.send(m); }",
        );
        let msgs: Vec<&str> = a
            .findings
            .iter()
            .filter(|f| f.rule == RULE_LEDGER)
            .map(|f| f.message.as_str())
            .collect();
        assert!(
            msgs.iter().any(|m| m.contains("without `requests`")),
            "{:?}",
            a.findings
        );
    }

    #[test]
    fn orphan_and_non_reply_ledger_callers_flagged() {
        let orphan = ledger_base(
            "fn record_done(&self, s: &mut S) { s.requests += 1; s.completed += 1; }",
            "fn dispatch(&self, r: R) { r.reply.send(m); }",
        );
        assert!(
            orphan
                .findings
                .iter()
                .any(|f| f.rule == RULE_LEDGER && f.message.contains("no production call site")),
            "{:?}",
            orphan.findings
        );

        let silent = ledger_base(
            "fn record_done(&self, s: &mut S) { s.requests += 1; s.completed += 1; }",
            "fn dispatch(&self, r: R) { self.rec.record_done(s); }",
        );
        assert!(
            silent
                .findings
                .iter()
                .any(|f| f.rule == RULE_LEDGER && f.message.contains("not a terminal-reply path")),
            "{:?}",
            silent.findings
        );
    }

    #[test]
    fn blocking_under_guard_flagged_and_suppressible() {
        let src = r#"
impl W {
    fn pump(&self) {
        let q = self.q.lock().expect("job queue");
        let msg = self.rx.recv();
    }
}
"#;
        let a = run(&[("exec/demo.rs", src)]);
        let hits: Vec<&Finding> =
            a.findings.iter().filter(|f| f.rule == RULE_HOLD_BLOCKING).collect();
        assert_eq!(hits.len(), 1, "{:?}", a.findings);
        assert!(hits[0].message.contains("job queue"));
        assert!(hits[0].message.contains("recv"));

        // outside the serving path the same shape is not a finding
        let a = run(&[("quant/demo.rs", src)]);
        assert!(!rules_of(&a).contains(&RULE_HOLD_BLOCKING), "{:?}", a.findings);

        let suppressed = r#"
impl W {
    fn pump(&self) {
        let q = self.q.lock().expect("job queue");
        // block-ok: single consumer; the guard is the handoff protocol
        let msg = self.rx.recv();
    }
}
"#;
        let a = run(&[("exec/demo.rs", suppressed)]);
        assert!(!rules_of(&a).contains(&RULE_HOLD_BLOCKING), "{:?}", a.findings);
        assert_eq!(a.suppressed_block, 1);
    }

    #[test]
    fn blocking_rule_spares_released_guards_and_str_join() {
        // the temporary guard dies at the `;` — the next-statement recv
        // is guard-free; `names.join(", ")` is not a thread join
        let src = r#"
impl W {
    fn pump(&self) {
        self.counts.lock().expect("pool counts").queued += 1;
        let msg = self.rx.recv();
        let held = self.names.lock().expect("name table");
        held.join(", ")
    }
    fn park(&self) {
        let h = self.handle.lock().expect("worker handle");
        h.join();
    }
    fn idle(&self) {
        let mut c = self.counts.lock().expect("pool counts");
        c = self.cv.wait(c);
    }
}
"#;
        let a = run(&[("runtime/demo.rs", src)]);
        let hits: Vec<&Finding> =
            a.findings.iter().filter(|f| f.rule == RULE_HOLD_BLOCKING).collect();
        assert_eq!(hits.len(), 1, "only the no-arg thread join flags: {:?}", hits);
        assert!(hits[0].message.contains("join"));
        assert!(hits[0].message.contains("worker handle"));
    }

    #[test]
    fn condvar_wait_with_second_guard_flagged() {
        let src = r#"
impl W {
    fn bad(&self) {
        let slot = self.slot.lock().expect("replica slot");
        let c = self.counts.lock().expect("pool counts");
        let c = self.cv.wait(c);
    }
}
"#;
        let a = run(&[("runtime/demo.rs", src)]);
        assert!(
            rules_of(&a).contains(&RULE_HOLD_BLOCKING),
            "waiting with a second guard held must flag: {:?}",
            a.findings
        );
    }

    #[test]
    fn guard_helper_acquisitions_feed_the_lock_graph() {
        let src = r#"
impl R {
    fn slots(&self) -> MutexGuard<'_, Slots> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }
    fn ab(&self) {
        let g = self.slots();
        let b = self.b.lock().expect("lock B");
    }
    fn ba(&self) {
        let b = self.b.lock().expect("lock B");
        let g = self.slots();
    }
}
"#;
        let a = run(&[("quant/demo.rs", src)]);
        assert!(
            rules_of(&a).contains(&RULE_LOCK_ORDER),
            "helper-mediated cycle must be found: {:?}",
            a.findings
        );
    }
}
