//! Schedule exploration: a bounded-preemption exhaustive DFS plus a
//! seeded PCT-style randomized sweep, both producing replayable failure
//! schedules.
//!
//! **Soundness caveat** (DESIGN.md §5.12): heromck proves invariants
//! only over the schedules it explores — all interleavings reachable
//! with at most `max_preemptions` preemptions (the DFS), plus
//! `pct_iters` random priority schedules.  Empirically most concurrency
//! bugs need very few preemptions to trigger (the PCT observation), but
//! a clean run is a *schedule-bounded* proof, not a full one.

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::prop::Rng;

use super::sched::{Controller, DecideMode, MckAbort, PointKind, RunRecord};
use super::thread::panic_message;
use super::{decode_token, install_quiet_hook, next_epoch, set_current, RunHandle};

/// Exploration budgets.  `from_env` lets CI cap the total schedule
/// count via `MCK_SCHEDULES` without touching the tests.
#[derive(Clone, Debug)]
pub struct Config {
    /// DFS preemption bound: schedules with more than this many
    /// preemptive context switches are not enumerated.
    pub max_preemptions: u32,
    /// Hard cap on schedules executed across DFS and PCT together.
    pub max_schedules: usize,
    /// Per-schedule decision-count bound (fails the schedule as a
    /// livelock when exceeded).
    pub max_depth: usize,
    /// Randomized-mode iterations appended after the DFS.
    pub pct_iters: usize,
    pub pct_seed: u64,
    /// Priority change points injected per PCT schedule.
    pub pct_change_points: usize,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            max_preemptions: 2,
            max_schedules: 4000,
            max_depth: 4000,
            pct_iters: 64,
            pct_seed: 0x5eed_cafe,
            pct_change_points: 3,
        }
    }
}

impl Config {
    /// Default budgets, with `MCK_SCHEDULES` (when set) overriding the
    /// total schedule cap — the CI knob.
    pub fn from_env() -> Config {
        let mut cfg = Config::default();
        if let Ok(v) = std::env::var("MCK_SCHEDULES") {
            if let Ok(n) = v.trim().parse::<usize>() {
                cfg.max_schedules = n.max(1);
            }
        }
        cfg
    }
}

/// A failing schedule, fully replayable via its token.
#[derive(Clone, Debug)]
pub struct Failure {
    pub kind: String,
    pub message: String,
    pub token: String,
    /// Rendered schedule-step tail leading up to the failure.
    pub schedule: Vec<String>,
    /// Held-lock stacks at failure time.
    pub held: Vec<String>,
    pub depth: usize,
}

#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub schedules: usize,
    pub max_depth: usize,
    /// Whether the DFS drained its frontier (vs hitting the schedule
    /// cap) — i.e. the preemption-bounded space was covered completely.
    pub dfs_complete: bool,
}

pub struct Outcome {
    pub stats: Stats,
    pub failure: Option<Failure>,
    /// Union of named lock-order edges observed across all explored
    /// schedules; cross-checked against herolint's static `lock_edges`.
    pub edges: BTreeSet<(String, String)>,
}

impl Outcome {
    pub fn ok(&self) -> bool {
        self.failure.is_none()
    }
}

/// Execute one schedule: run `body` as model thread 0 under a fresh
/// controller, forcing the decision prefix, and collect the record.
fn run_one<F>(body: &Arc<F>, forced: Vec<usize>, mode: DecideMode, cfg: &Config) -> RunRecord
where
    F: Fn() + Send + Sync + 'static,
{
    install_quiet_hook();
    let ctl = Arc::new(Controller::new(
        next_epoch(),
        forced,
        mode,
        cfg.max_preemptions,
        cfg.max_depth,
    ));
    let tid = ctl.register_main();
    let b = body.clone();
    let c = ctl.clone();
    let os = std::thread::Builder::new()
        .name(format!("mck-t{tid}"))
        .spawn(move || {
            set_current(Some(RunHandle { ctl: c.clone(), tid }));
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| b()));
            let panic_msg = match &result {
                Ok(_) => None,
                Err(p) if p.is::<MckAbort>() => None,
                Err(p) => Some(panic_message(p.as_ref())),
            };
            set_current(None);
            c.thread_finished(tid, panic_msg);
        })
        .expect("failed to spawn model main thread");
    let _ = os.join();
    ctl.wait_all_finished()
}

fn failure_of(rec: &RunRecord) -> Option<Failure> {
    rec.failure.as_ref().map(|f| Failure {
        kind: f.kind.clone(),
        message: f.message.clone(),
        token: f.token.clone(),
        schedule: f.schedule.clone(),
        held: f.held.clone(),
        depth: f.depth,
    })
}

/// Replay a single schedule from its token.  Decisions beyond the
/// recorded prefix (there should be none for a faithfully reproduced
/// failure) fall back to the DFS default.
pub fn replay<F>(cfg: &Config, body: F, token: &str) -> Outcome
where
    F: Fn() + Send + Sync + 'static,
{
    let forced = decode_token(token).unwrap_or_else(|| {
        panic!("heromck: malformed replay token {token:?} (want mck1.<i>.<i>...)")
    });
    let body = Arc::new(body);
    let rec = run_one(&body, forced, DecideMode::Dfs, cfg);
    Outcome {
        stats: Stats { schedules: 1, max_depth: rec.trace.len(), dfs_complete: false },
        failure: failure_of(&rec),
        edges: rec.edges,
    }
}

/// Explore `body` under `cfg` and return the outcome without panicking.
/// Used directly by tests that *expect* a failure (deadlock demos,
/// mutation-sensitivity checks); [`check`] is the asserting wrapper.
///
/// When `MCK_REPLAY` is set in the environment, exploration is skipped
/// and the named schedule is replayed instead.
pub fn check_result<F>(name: &str, cfg: Config, body: F) -> Outcome
where
    F: Fn() + Send + Sync + 'static,
{
    if let Ok(tok) = std::env::var("MCK_REPLAY") {
        let out = replay(&cfg, body, tok.trim());
        super::record_outcome(name, &out);
        return out;
    }
    let body = Arc::new(body);
    let mut stats = Stats { schedules: 0, max_depth: 0, dfs_complete: true };
    let mut edges: BTreeSet<(String, String)> = BTreeSet::new();
    let mut failure: Option<Failure> = None;

    // Bounded-preemption DFS: the frontier holds forced decision
    // prefixes; each executed schedule contributes one alternative
    // prefix per unexplored sibling decision past its own prefix.
    let mut frontier: Vec<Vec<usize>> = vec![Vec::new()];
    while let Some(forced) = frontier.pop() {
        if stats.schedules >= cfg.max_schedules {
            stats.dfs_complete = false;
            break;
        }
        let flen = forced.len();
        let rec = run_one(&body, forced, DecideMode::Dfs, &cfg);
        stats.schedules += 1;
        stats.max_depth = stats.max_depth.max(rec.trace.len());
        edges.extend(rec.edges.iter().cloned());
        if rec.failure.is_some() {
            failure = failure_of(&rec);
            break;
        }
        for i in flen..rec.trace.len() {
            let p = &rec.trace[i];
            // value alternatives are free; thread alternatives cost a
            // preemption iff the default would have kept the previous
            // thread running
            let affordable = match p.kind {
                PointKind::Value => true,
                PointKind::Thread => {
                    !p.preempting_alts || p.preempts_before + 1 <= cfg.max_preemptions
                }
            };
            if !affordable {
                continue;
            }
            for alt in (p.chosen + 1..p.options).rev() {
                let mut next: Vec<usize> = rec.trace[..i].iter().map(|t| t.chosen).collect();
                next.push(alt);
                frontier.push(next);
            }
        }
    }

    // Seeded PCT-style sweep: random thread priorities with a few
    // priority change points, catching orderings the preemption bound
    // excludes.  Fully determined by (pct_seed, iteration).
    if failure.is_none() {
        for iter in 0..cfg.pct_iters {
            if stats.schedules >= cfg.max_schedules + cfg.pct_iters {
                break;
            }
            let mut rng = Rng::new(
                cfg.pct_seed ^ 0x9e37_79b9_7f4a_7c15u64.wrapping_mul(iter as u64 + 1),
            );
            let horizon = stats.max_depth.max(64);
            let change_points: Vec<usize> =
                (0..cfg.pct_change_points).map(|_| rng.below(horizon)).collect();
            let mode = DecideMode::Pct { rng, change_points, priorities: Vec::new() };
            let rec = run_one(&body, Vec::new(), mode, &cfg);
            stats.schedules += 1;
            stats.max_depth = stats.max_depth.max(rec.trace.len());
            edges.extend(rec.edges.iter().cloned());
            if rec.failure.is_some() {
                failure = failure_of(&rec);
                break;
            }
        }
    }

    let out = Outcome { stats, failure, edges };
    super::record_outcome(name, &out);
    out
}

/// Explore `body` and panic with a full replayable report if any
/// schedule fails.  The panic message carries the schedule token;
/// re-running the same test with `MCK_REPLAY=<token>` reproduces the
/// failing interleaving deterministically.
pub fn check<F>(name: &str, cfg: Config, body: F) -> Outcome
where
    F: Fn() + Send + Sync + 'static,
{
    let out = check_result(name, cfg, body);
    if let Some(f) = &out.failure {
        let mut report = format!(
            "heromck[{name}] {}: {}\n  replay: MCK_REPLAY={} (depth {})\n",
            f.kind, f.message, f.token, f.depth
        );
        if !f.held.is_empty() {
            report.push_str("  held locks:\n");
            for h in &f.held {
                report.push_str(&format!("    {h}\n"));
            }
        }
        report.push_str("  schedule tail:\n");
        for s in &f.schedule {
            report.push_str(&format!("    {s}\n"));
        }
        panic!("{report}");
    }
    out
}
