"""Reference PTQ transform: fp32 checkpoint + calibration stats -> HERO
quantized checkpoint (paper eqs. 2, 20-23, 32).

This is the *python mirror* of the production rust engine
(``rust/src/quant/fold.rs``); golden-file tests enforce bit-exact parity
between the two.  It also powers the L2 model tests (hero vs fp divergence)
without a rust round-trip.
"""

from collections import OrderedDict

import numpy as np

from ..config import ModelConfig, QuantSwitches
from ..kernels.quant_ops import (
    quantize_weight_colwise, fold_fwq_in_fwq_out,
    scale_from_absmax, scale_from_max_nonneg, clip_absmax,
)


def derive_scales(stats, cfg: ModelConfig, pct=100.0):
    """Aggregated (or per-batch-history) stats -> per-layer scale dict.

    ``stats[k]`` has shape [L, ...] (aggregated) or [B, L, ...] (history,
    clipped at percentile ``pct`` over the batch axis).
    """
    agg = {}
    for k, v in stats.items():
        v = np.asarray(v, np.float64)
        want_nd = 1 if k in ("q_absmax", "k_absmax", "v_absmax", "p_max") else 2
        agg[k] = clip_absmax(v, pct) if v.ndim == want_nd + 1 else v
    out = []
    for i in range(cfg.layers):
        out.append({
            "sq_q": float(scale_from_absmax(agg["q_absmax"][i])),
            "sq_k": float(scale_from_absmax(agg["k_absmax"][i])),
            "sq_v": float(scale_from_absmax(agg["v_absmax"][i])),
            "sp": float(scale_from_max_nonneg(agg["p_max"][i])),
            "s_attn": scale_from_absmax(agg["attn_absmax"][i]).astype(np.float32),
            "s_o": scale_from_absmax(agg["o_absmax"][i]).astype(np.float32),
            "s_a": scale_from_absmax(agg["gelu_absmax"][i]).astype(np.float32),
            "s_x2": scale_from_absmax(agg["x2_absmax"][i]).astype(np.float32),
        })
    return out


def quantize_checkpoint(fp, stats, cfg: ModelConfig, sw: QuantSwitches, pct=100.0):
    """fp: dict name->np.ndarray (fp_param_specs order);
    stats: calibration stat dict. Returns hero params (hero_param_specs order)."""
    scales = derive_scales(stats, cfg, pct)
    d, f, h, dh = cfg.hidden, cfg.ffn, cfg.heads, cfg.head_dim
    q = OrderedDict()
    for name in ("emb.tok", "emb.pos", "emb.type", "emb.ln.g", "emb.ln.b"):
        q[name] = fp[name]

    for i in range(cfg.layers):
        p = f"L{i}."
        sc = scales[i]
        sq = {"q": sc["sq_q"], "k": sc["sq_k"], "v": sc["sq_v"]}
        if sw.qkv:
            for t in "qkv":
                w, b = fp[p + f"attn.{t}.w"], fp[p + f"attn.{t}.b"]
                if sw.attn:
                    # eq. 20-21: fold the SQ output scale, requant == Round
                    wq, ws = quantize_weight_colwise(w / sq[t])
                    q[p + f"attn.{t}.wq"] = wq
                    q[p + f"attn.{t}.ws"] = ws
                    q[p + f"attn.{t}.b"] = (b / sq[t]).astype(np.float32)
                else:
                    wq, ws = quantize_weight_colwise(w)
                    q[p + f"attn.{t}.wq"] = wq
                    q[p + f"attn.{t}.ws"] = ws
                    q[p + f"attn.{t}.b"] = b
        else:
            for t in "qkv":
                q[p + f"attn.{t}.w"] = fp[p + f"attn.{t}.w"]
                q[p + f"attn.{t}.b"] = fp[p + f"attn.{t}.b"]
        if sw.attn:
            q[p + "attn.qk_scale"] = np.asarray(
                [sq["q"] * sq["k"] / np.sqrt(dh)], np.float32)
            q[p + "attn.sp"] = np.asarray([sc["sp"]], np.float32)
            q[p + "attn.pv_scale"] = (
                sc["sp"] * sq["v"] / sc["s_attn"]).astype(np.float32).reshape(h, dh)
            if not sw.qkv:
                for t in "qkv":
                    q[p + f"attn.inv_sq_{t}"] = np.asarray([1.0 / sq[t]], np.float32)
        if sw.attn_output:
            wt, bt = fold_fwq_in_fwq_out(
                fp[p + "attn.o.w"], fp[p + "attn.o.b"], sc["s_attn"], sc["s_o"])
            wq, ws = quantize_weight_colwise(wt)
            q[p + "attn.o.wq"] = wq
            q[p + "attn.o.ws"] = ws
            q[p + "attn.o.bq"] = bt.astype(np.float32)
            q[p + "ln1.so"] = sc["s_o"]
            if not sw.attn:
                q[p + "attn.inv_s_attn"] = (1.0 / sc["s_attn"]).astype(np.float32)
        else:
            q[p + "attn.o.w"] = fp[p + "attn.o.w"]
            q[p + "attn.o.b"] = fp[p + "attn.o.b"]
            if sw.attn:
                q[p + "attn.s_attn"] = sc["s_attn"]
        q[p + "ln1.g"] = fp[p + "ln1.g"]
        q[p + "ln1.b"] = fp[p + "ln1.b"]

        if sw.fc1:
            wq, ws = quantize_weight_colwise(fp[p + "fc1.w"])
            q[p + "fc1.wq"] = wq
            q[p + "fc1.ws"] = ws
            q[p + "fc1.b"] = fp[p + "fc1.b"]
        else:
            q[p + "fc1.w"] = fp[p + "fc1.w"]
            q[p + "fc1.b"] = fp[p + "fc1.b"]
        if sw.fc2:
            q[p + "gelu.sa"] = sc["s_a"]
            wt, bt = fold_fwq_in_fwq_out(
                fp[p + "fc2.w"], fp[p + "fc2.b"], sc["s_a"], sc["s_x2"])
            wq, ws = quantize_weight_colwise(wt)
            q[p + "fc2.wq"] = wq
            q[p + "fc2.ws"] = ws
            q[p + "fc2.bq"] = bt.astype(np.float32)
            q[p + "ln2.sx2"] = sc["s_x2"]
        else:
            q[p + "fc2.w"] = fp[p + "fc2.w"]
            q[p + "fc2.b"] = fp[p + "fc2.b"]
        q[p + "ln2.g"] = fp[p + "ln2.g"]
        q[p + "ln2.b"] = fp[p + "ln2.b"]

    for name in ("pool.w", "pool.b", "cls.w", "cls.b"):
        q[name] = fp[name]
    return q
