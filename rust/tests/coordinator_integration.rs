//! End-to-end coordinator test: requests through admission -> batcher ->
//! engine -> completion, with correct per-request row mapping.
//! Gated on `make artifacts`.

mod common;

use std::time::Duration;

use common::artifacts;
use zqhero::coordinator::{Coordinator, RequestSpec, ServerConfig};
use zqhero::data::Split;
use zqhero::model::manifest::Manifest;
use zqhero::model::Container;
use zqhero::runtime::Runtime;

fn spec(task: &str, policy: &str, ids: &[i32], tys: &[i32]) -> RequestSpec {
    RequestSpec::task(task).policy(policy).ids(ids.to_vec()).type_ids(tys.to_vec())
}

#[test]
fn serve_fp_requests_end_to_end() {
    let Some(dir) = artifacts() else { return };
    let pairs = vec![("cola".to_string(), "fp".to_string())];
    let coord = Coordinator::start(
        dir.clone(),
        &pairs,
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            ..Default::default()
        },
    )
    .unwrap();

    let man = Manifest::load(&dir).unwrap();
    let task = man.task("cola").unwrap();
    let split = Split::load(&man, task, "dev").unwrap();
    let n = 40.min(split.len());

    // submit everything, then collect
    let mut rxs = Vec::new();
    for i in 0..n {
        let (ids, tys) = split.row(i);
        let rx = coord.submit(spec("cola", "fp", ids, tys)).unwrap();
        rxs.push(rx);
    }
    let mut responses = Vec::new();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.logits.len(), coord.num_labels());
        assert!(resp.logits.iter().all(|x| x.is_finite()));
        assert!(resp.timing.batch_real >= 1 && resp.timing.batch_real <= 8);
        assert!(resp.timing.bucket >= resp.timing.batch_real);
        responses.push(resp);
    }

    // row mapping: responses must equal direct runtime inference per example
    let mut rt = Runtime::new(Manifest::load(&dir).unwrap()).unwrap();
    let fp = Container::read_file(&rt.manifest.path(&task.checkpoint))
        .unwrap()
        .reordered(&rt.manifest.mode("fp").unwrap().params)
        .unwrap();
    rt.upload_checkpoint("cola", "fp", &fp).unwrap();
    for (i, resp) in responses.iter().enumerate().take(10) {
        let (ids, tys) = split.row(i);
        let mask = Split::mask_row(ids);
        let direct = rt.infer("cola", "fp", 1, ids, tys, &mask).unwrap();
        let dv = direct.as_f32().unwrap();
        for (a, b) in resp.logits.iter().zip(dv) {
            assert!(
                (a - b).abs() < 1e-3,
                "request {i}: coordinator {a} vs direct {b}"
            );
        }
    }

    // metrics recorded
    let snap = coord.recorder.snapshot();
    assert_eq!(snap["fp"].requests, n as u64);
    assert_eq!(snap["fp"].errors, 0);
    assert!(snap["fp"].batches >= (n / 8) as u64);
}

#[test]
fn rejects_malformed_and_applies_backpressure_shape() {
    let Some(dir) = artifacts() else { return };
    let pairs = vec![("cola".to_string(), "fp".to_string())];
    let coord = Coordinator::start(
        dir,
        &pairs,
        ServerConfig { queue_cap: 4, ..Default::default() },
    )
    .unwrap();
    // empty and oversized payloads are rejected before admission
    // (short-but-nonempty ids are fine: they batch in their own
    // sequence-length class instead of being padded to seq)
    assert!(coord.submit(RequestSpec::task("cola").mode("fp")).is_err());
    let huge = vec![1i32; coord.seq() + 1];
    assert!(coord.submit(RequestSpec::task("cola").mode("fp").ids(huge)).is_err());
}

#[test]
fn unknown_checkpoint_fails_at_startup() {
    let Some(dir) = artifacts() else { return };
    let pairs = vec![("cola".to_string(), "m9".to_string())];
    assert!(Coordinator::start(dir, &pairs, ServerConfig::default()).is_err());
}

#[test]
fn policy_tables_agree_between_coordinator_and_engine() {
    let Some(dir) = artifacts() else { return };
    let pairs = vec![("cola".to_string(), "fp".to_string())];
    let coord = Coordinator::start(dir, &pairs, ServerConfig::default()).unwrap();
    let man = coord.manifest();
    let engine = coord.engine();

    // both sides derive the PolicyId space from the same manifest.json —
    // same names, same order, same executable mode per policy
    assert_eq!(engine.policy_names(), man.policy_order.as_slice());
    for name in &man.policy_order {
        let cid = man.policy_id(name).unwrap();
        let eid = engine.policy_id(name).unwrap();
        assert_eq!(cid.0, eid.0, "policy {name}: id mismatch");
        assert_eq!(
            engine.policy_exec_mode(eid).unwrap(),
            man.policy_by_id(cid).exec_mode,
            "policy {name}: exec mode mismatch"
        );
    }
    // the uniform prefix coincides with the mode table on both sides
    for name in &man.mode_order {
        assert_eq!(
            engine.policy_id(name).unwrap().0,
            engine.mode_id(name).unwrap().0,
            "uniform policy {name} must share the mode index"
        );
    }
}
