//! Thread-pool executor (tokio is unavailable offline — DESIGN.md §2).
//!
//! A small fixed-size worker pool over an mpsc job queue, with graceful
//! shutdown and panic isolation.  The serving coordinator uses it for
//! request pre/post-processing; PJRT execution stays on the dedicated
//! engine thread.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Stop,
}

pub struct ThreadPool {
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
    completed: Arc<AtomicUsize>,
    panicked: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize, name: &str) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let completed = Arc::new(AtomicUsize::new(0));
        let panicked = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                let completed = Arc::clone(&completed);
                let panicked = Arc::clone(&panicked);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move ||

 worker_main(rx, queued, completed, panicked))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, workers, queued, completed, panicked }
    }

    /// Enqueue a job; returns false if the pool is shut down.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) -> bool {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.tx.send(Msg::Run(Box::new(f))).is_ok()
    }

    /// Run a closure on the pool and get the result over a channel.
    pub fn run<T, F>(&self, f: F) -> Receiver<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = channel();
        self.spawn(move || {
            let _ = tx.send(f());
        });
        rx
    }

    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::SeqCst) - self.completed.load(Ordering::SeqCst)
    }

    pub fn completed(&self) -> usize {
        self.completed.load(Ordering::SeqCst)
    }

    pub fn panicked(&self) -> usize {
        self.panicked.load(Ordering::SeqCst)
    }

    /// Block until every queued job has finished (test/bench helper).
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            std::thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Stop);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_main(
    rx: Arc<Mutex<Receiver<Msg>>>,
    _queued: Arc<AtomicUsize>,
    completed: Arc<AtomicUsize>,
    panicked: Arc<AtomicUsize>,
) {
    loop {
        let msg = {
            let guard = rx.lock().expect("queue poisoned");
            guard.recv()
        };
        match msg {
            Ok(Msg::Run(job)) => {
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                if res.is_err() {
                    panicked.fetch_add(1, Ordering::SeqCst);
                }
                completed.fetch_add(1, Ordering::SeqCst);
            }
            Ok(Msg::Stop) | Err(_) => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, "t");
        let sum = Arc::new(AtomicU64::new(0));
        for i in 0..100u64 {
            let sum = Arc::clone(&sum);
            pool.spawn(move || {
                sum.fetch_add(i, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(sum.load(Ordering::SeqCst), 4950);
        assert_eq!(pool.completed(), 100);
    }

    #[test]
    fn run_returns_value() {
        let pool = ThreadPool::new(2, "t");
        let rx = pool.run(|| 6 * 7);
        assert_eq!(rx.recv().unwrap(), 42);
    }

    #[test]
    fn panics_are_isolated() {
        let pool = ThreadPool::new(2, "t");
        pool.spawn(|| panic!("boom"));
        let rx = pool.run(|| "still alive");
        assert_eq!(rx.recv().unwrap(), "still alive");
        pool.wait_idle();
        assert_eq!(pool.panicked(), 1);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2, "t");
        for _ in 0..10 {
            pool.spawn(|| std::thread::sleep(std::time::Duration::from_millis(1)));
        }
        drop(pool); // must not hang or panic
    }
}
