//! Network front-end: newline-delimited JSON over TCP, served by the
//! coordinator (`repro serve --port N`).
//!
//! Request  : {"task": "sst2", "mode": "m3", "ids": [...], "type_ids": [...]}
//!            (`type_ids` optional — defaults to zeros; short `ids` are
//!            padded to the model sequence length)
//! Response : {"ok": true, "logits": [...], "queue_us": .., "exec_us": ..,
//!             "bucket": ..} | {"ok": false, "error": "..."}
//!
//! One OS thread per connection (requests within a connection pipeline
//! through the dynamic batcher like any other); shutdown via the returned
//! handle.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::json::{self, Value};

use super::server::Coordinator;

pub struct NetServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_join: Option<std::thread::JoinHandle<()>>,
    pub connections: Arc<AtomicU64>,
    pub served: Arc<AtomicU64>,
}

impl NetServer {
    /// Bind `host:port` (port 0 = ephemeral) and serve until dropped.
    pub fn start(coord: Arc<Coordinator>, host: &str, port: u16) -> Result<NetServer> {
        let listener =
            TcpListener::bind((host, port)).with_context(|| format!("bind {host}:{port}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let connections = Arc::new(AtomicU64::new(0));
        let served = Arc::new(AtomicU64::new(0));

        let t_stop = Arc::clone(&stop);
        let t_conns = Arc::clone(&connections);
        let t_served = Arc::clone(&served);
        let accept_join = std::thread::Builder::new()
            .name("zqh-accept".into())
            .spawn(move || {
                let mut workers = Vec::new();
                while !t_stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            t_conns.fetch_add(1, Ordering::SeqCst);
                            let coord = Arc::clone(&coord);
                            let served = Arc::clone(&t_served);
                            let stop = Arc::clone(&t_stop);
                            workers.push(std::thread::spawn(move || {
                                let _ = handle_conn(stream, &coord, &served, &stop);
                            }));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
                for w in workers {
                    let _ = w.join();
                }
            })
            .context("spawn acceptor")?;

        Ok(NetServer { addr, stop, accept_join: Some(accept_join), connections, served })
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
    }
}

fn ids_from(v: &Value, key: &str, seq: usize) -> Result<Option<Vec<i32>>> {
    match v.get(key) {
        None => Ok(None),
        Some(arr) => {
            let a = arr.as_array().context("ids must be an array")?;
            anyhow::ensure!(a.len() <= seq, "too many tokens ({} > seq {seq})", a.len());
            let mut out = Vec::with_capacity(seq);
            for x in a {
                out.push(x.as_f64().context("token not a number")? as i32);
            }
            out.resize(seq, crate::data::PAD);
            Ok(Some(out))
        }
    }
}

fn process_line(line: &str, coord: &Coordinator) -> Value {
    let fail = |msg: String| {
        json::obj(vec![("ok", Value::Bool(false)), ("error", Value::String(msg))])
    };
    let req = match json::parse(line) {
        Ok(v) => v,
        Err(e) => return fail(format!("bad json: {e}")),
    };
    let seq = coord.seq();
    // borrow straight out of the parsed value: route strings die here —
    // admission interns them to TaskId/ModeId (DESIGN.md §5.2)
    let task = req.get("task").and_then(Value::as_str).unwrap_or_default();
    let mode = req.get("mode").and_then(Value::as_str).unwrap_or("m3");
    let ids = match ids_from(&req, "ids", seq) {
        Ok(Some(v)) => v,
        Ok(None) => return fail("missing ids".into()),
        Err(e) => return fail(e.to_string()),
    };
    let type_ids = match ids_from(&req, "type_ids", seq) {
        Ok(Some(v)) => v,
        Ok(None) => vec![0; seq],
        Err(e) => return fail(e.to_string()),
    };
    let rx = match coord.submit(task, mode, ids, type_ids) {
        Ok(rx) => rx,
        Err(e) => return fail(e.to_string()),
    };
    match rx.recv() {
        Err(_) => fail("coordinator dropped request".into()),
        Ok(resp) => match resp.error {
            Some(e) => fail(e),
            None => json::obj(vec![
                ("ok", Value::Bool(true)),
                ("logits", json::arr_f32(&resp.logits)),
                ("queue_us", json::num(resp.timing.queue_us as f64)),
                ("exec_us", json::num(resp.timing.exec_us as f64)),
                ("bucket", json::num(resp.timing.bucket as f64)),
                ("batch", json::num(resp.timing.batch_real as f64)),
            ]),
        },
    }
}

fn handle_conn(
    stream: TcpStream,
    coord: &Coordinator,
    served: &AtomicU64,
    stop: &AtomicBool,
) -> Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break, // peer closed
            Ok(_) => {
                let trimmed = line.trim();
                if trimmed.is_empty() {
                    continue;
                }
                let resp = process_line(trimmed, coord);
                writer.write_all(json::to_string(&resp).as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
                served.fetch_add(1, Ordering::SeqCst);
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => break,
        }
    }
    Ok(())
}

/// Minimal blocking client for examples/tests.
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl NetClient {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(NetClient { reader: BufReader::new(stream), writer })
    }

    pub fn request(&mut self, task: &str, mode: &str, ids: &[i32]) -> Result<Value> {
        let req = json::obj(vec![
            ("task", Value::String(task.into())),
            ("mode", Value::String(mode.into())),
            ("ids", Value::Array(ids.iter().map(|x| json::num(*x as f64)).collect())),
        ]);
        self.writer.write_all(json::to_string(&req).as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_padding_and_bounds() {
        let v = json::parse(r#"{"ids": [1, 2, 3]}"#).unwrap();
        let ids = ids_from(&v, "ids", 6).unwrap().unwrap();
        assert_eq!(ids, vec![1, 2, 3, 0, 0, 0]);
        let too_long = json::parse(r#"{"ids": [1,2,3,4,5,6,7]}"#).unwrap();
        assert!(ids_from(&too_long, "ids", 6).is_err());
        assert!(ids_from(&v, "type_ids", 6).unwrap().is_none());
    }
}
