//! Figure 2: the MLP-module dataflow with quantization annotations
//! (X_1 unquantized, GELU output and X_2 FWQ — paper §2.2.3).

use zqhero::bench::Table;
use zqhero::model::manifest::Manifest;
use zqhero::traceflow;

fn main() {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("fig2_mlp_flow: run `make artifacts` first");
        return;
    }
    let man = Manifest::load(&dir).expect("manifest");
    for mode in &man.mode_order {
        let sw = man.modes[mode].switches;
        println!("\nFigure 2 — MLP module, {} (switches {})", mode, sw.tag());
        let mut t = Table::new(&["tensor", "producer", "scheme", "dtype"]);
        for r in traceflow::mlp_flow(&sw) {
            t.row(vec![r.tensor.into(), r.producer.into(), r.scheme, r.dtype]);
        }
        t.print();
    }
    // M3 invariants from the paper text
    let m3 = man.modes["m3"].switches;
    let rows = traceflow::mlp_flow(&m3);
    let f = |t: &str| rows.iter().find(|r| r.tensor == t).unwrap().clone();
    assert_eq!(f("X_1").dtype, "fp", "X_1 must stay high precision");
    assert_eq!(f("A").scheme, "FWQ");
    assert_eq!(f("X_2").scheme, "FWQ");
    println!("\nM3 MLP flow matches paper §2.2.3 (X_1 fp; A, X_2 FWQ)");
}
