"""FP reference encoder (the paper's FP16 baseline row; f32 on CPU PJRT).

Pure jnp — this is what cuBLAS/fused-fp16 kernels would compute; it is also
the forward used for training (train.py) and for calibration
(calibration.py wraps it with stat taps).
"""

import jax.numpy as jnp

from ..config import ModelConfig
from ..kernels.ref import attention_fp, gelu

MASK_BIG = 1e9


def layer_norm(x, g, b, eps):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * g + b


def split_heads(x, b, s, h, dh):
    """[b*s, d] -> [b*h, s, dh]"""
    return x.reshape(b, s, h, dh).transpose(0, 2, 1, 3).reshape(b * h, s, dh)


def merge_heads(x, b, s, h, dh):
    """[b*h, s, dh] -> [b*s, d]"""
    return x.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b * s, h * dh)


def embed(params, cfg: ModelConfig, input_ids, type_ids):
    """Token+position+type embedding sum, flattened to [b*s, d]."""
    b, s = input_ids.shape
    x_t = jnp.take(params["emb.tok"], input_ids.reshape(-1), axis=0)
    x_p = jnp.tile(params["emb.pos"][:s], (b, 1))
    x_ty = jnp.take(params["emb.type"], type_ids.reshape(-1), axis=0)
    return x_t, x_p + x_ty


def bert_forward(params, cfg: ModelConfig, input_ids, type_ids, attn_mask,
                 collect=None):
    """FP forward.  ``attn_mask`` f32 [b, s] with 1 = real token.

    ``collect``: optional callable (layer_idx, name, tensor) used by the
    calibration instrumentation; None on the plain path.
    """
    b, s = input_ids.shape
    d, h, dh = cfg.hidden, cfg.heads, cfg.head_dim
    x_t, x_pb = embed(params, cfg, input_ids, type_ids)
    x = layer_norm(x_t + x_pb, params["emb.ln.g"], params["emb.ln.b"], cfg.ln_eps)

    kmask = jnp.repeat(attn_mask, h, axis=0)  # [b*h, s]
    for i in range(cfg.layers):
        p = f"L{i}."
        q = x @ params[p + "attn.q.w"] + params[p + "attn.q.b"]
        k = x @ params[p + "attn.k.w"] + params[p + "attn.k.b"]
        v = x @ params[p + "attn.v.w"] + params[p + "attn.v.b"]
        if collect is not None:
            collect(i, "q", q), collect(i, "k", k), collect(i, "v", v)
        qh = split_heads(q, b, s, h, dh)
        kh = split_heads(k, b, s, h, dh)
        vh = split_heads(v, b, s, h, dh)
        if collect is not None:
            # P stats need the softmax output; recompute the probs tap here
            a = jnp.einsum("bnd,bmd->bnm", qh, kh) / jnp.sqrt(dh).astype(jnp.float32)
            a = a + (kmask[:, None, :] - 1.0) * MASK_BIG
            a = a - jnp.max(a, axis=-1, keepdims=True)
            e = jnp.exp(a)
            probs = e / jnp.sum(e, axis=-1, keepdims=True)
            collect(i, "p", probs)
            attn = jnp.einsum("bnm,bmd->bnd", probs, vh)
        else:
            attn = attention_fp(qh, kh, vh, kmask, 1.0 / jnp.sqrt(dh).astype(jnp.float32))
        x_attn = merge_heads(attn, b, s, h, dh)
        if collect is not None:
            collect(i, "attn", x_attn)
        x_o = x_attn @ params[p + "attn.o.w"] + params[p + "attn.o.b"]
        if collect is not None:
            collect(i, "o", x_o)
        x = layer_norm(x + x_o, params[p + "ln1.g"], params[p + "ln1.b"], cfg.ln_eps)

        x1 = x @ params[p + "fc1.w"] + params[p + "fc1.b"]
        a_act = gelu(x1)
        if collect is not None:
            collect(i, "gelu", a_act)
        x2 = a_act @ params[p + "fc2.w"] + params[p + "fc2.b"]
        if collect is not None:
            collect(i, "x2", x2)
        x = layer_norm(x + x2, params[p + "ln2.g"], params[p + "ln2.b"], cfg.ln_eps)

    cls = x.reshape(b, s, d)[:, 0]
    pooled = jnp.tanh(cls @ params["pool.w"] + params["pool.b"])
    return pooled @ params["cls.w"] + params["cls.b"]
