//! Quantization engine (paper §2.1-2.2): schemes, scale folding, and the
//! fp32 -> HERO checkpoint transform.  Mirrors the python reference in
//! `python/compile/kernels/quant_ops.py` / `modeling/quantize.py` with
//! bit-exact parity (golden-file tests).

pub mod fold;
pub mod outliers;
pub mod schemes;
pub mod transform;

pub use schemes::{
    quantize_weight_colwise, round_ties_even, scale_from_absmax, scale_from_max_nonneg,
    sym_quantize_one, QMAX,
};
pub use transform::{
    quantize_checkpoint, validate_against_mode, validate_for_policy, AggStats, LayerScales,
};
