"""INT8 attention core (paper eqs. 15-17): the flash-attention-with-SQ
design rethought as a Pallas kernel.

Dataflow per (batch x head) grid step, with the whole [n, dh] Q/K/V tiles
and the [n, n] score tile VMEM-resident (n=128, dh=32 => ~80 KB, far under
VMEM):

  1. ``A = (Q_i8 . K_i8^T) * qk_scale``   — MXU int8 dot, int32 accumulate;
     ``qk_scale = S_q S_k / sqrt(dh)`` is folded (eq. 15), so there is no
     dequantization and no division by sqrt(d) at runtime.
  2. ``P_q = Softmax^quant(A)``           — asymmetric INT8, zero point
     -128, reusing the row max/denominator the softmax already computed
     (no extra pass; eq. 16).
  3. ``X_attn_i8 = Round((P_q+128) . V_i8 * pv_scale)`` — second MXU int8
     dot; the asymmetric shift keeps the left operand in [0, 255].
     ``pv_scale = s_p * S_v / S_attn`` (per-feature, eq. 17) is the entire
     epilogue.

``A`` itself stays f32 (the paper leaves attention scores unquantized for
accuracy).  The FP fallback core lives in modeling/bert.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QMAX = 127.0
MASK_BIG = 1e9


# heads per grid step: [G, n, n] f32 score tile = G * 64 KB at n=128 —
# G=8 keeps the tile ~0.5 MB in VMEM and cuts grid steps 8x (§Perf).
HEAD_GROUP = 8


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, qk_ref, sp_ref, pv_ref, o_ref):
    q = q_ref[...].astype(jnp.int32)        # [g, n, dh]
    k = k_ref[...].astype(jnp.int32)
    v = v_ref[...].astype(jnp.int32)
    qk_scale = qk_ref[0, 0]
    s_p = sp_ref[0, 0]

    acc = jax.lax.dot_general(
        q, k, (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.int32
    ).astype(jnp.float32)                    # [g, n, n] = Q . K^T
    a = acc * qk_scale + (mask_ref[...][:, None, :] - 1.0) * MASK_BIG

    a = a - jnp.max(a, axis=-1, keepdims=True)
    e = jnp.exp(a)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    p_q = jnp.clip(jnp.round(p / s_p) - 128.0, -128, 127)  # asym int8 domain
    p_shift = p_q.astype(jnp.int32) + 128                  # [0, 255]

    acc2 = jax.lax.dot_general(
        p_shift, v, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.int32
    ).astype(jnp.float32)                    # [g, n, dh]
    o_ref[...] = jnp.clip(jnp.round(acc2 * pv_ref[...]), -QMAX, QMAX).astype(jnp.int8)


def attention_quant(q_i8, k_i8, v_i8, mask, qk_scale, s_p, pv_scale):
    """INT8 attention core.

    q/k/v_i8: [bh, n, dh] int8 (SQ).  mask: [bh, n] f32 {0,1} over keys.
    qk_scale, s_p: f32 scalars.  pv_scale: [bh, 1, dh] f32.
    Returns X_attn int8 [bh, n, dh] (FWQ domain: X_attn = i8 * S_attn).
    """
    bh, n, dh = q_i8.shape
    g = HEAD_GROUP
    while bh % g:
        g -= 1
    qk = jnp.asarray(qk_scale, jnp.float32).reshape(1, 1)
    sp = jnp.asarray(s_p, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _attn_kernel,
        grid=(bh // g,),
        in_specs=[
            pl.BlockSpec((g, n, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((g, n, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((g, n, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((g, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((g, 1, dh), lambda i: (i, 0, 0)),
        ],
        out_specs=[pl.BlockSpec((g, n, dh), lambda i: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((bh, n, dh), jnp.int8)],
        interpret=True,
    )(q_i8, k_i8, v_i8, mask, qk, sp, pv_scale)[0]
