//! The full PTQ pipeline, step by step, with introspection: calibrate ->
//! derive scales -> fold -> column-quantize -> validate -> measure the
//! quantization error per parameter and the logit divergence vs FP.
//!
//!     cargo run --release --example calibrate_and_quantize [task] [mode]

use anyhow::Result;
use zqhero::bench::Table;
use zqhero::data::{batches, Split};
use zqhero::evalharness as eh;
use zqhero::model::manifest::Manifest;
use zqhero::model::{Container, DType};
use zqhero::quant::transform::derive_layer_scales;
use zqhero::quant::AggStats;
use zqhero::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let tname = args.first().map(String::as_str).unwrap_or("mrpc");
    let mode = args.get(1).map(String::as_str).unwrap_or("m3");

    let dir = std::path::PathBuf::from("artifacts");
    let mut rt = Runtime::new(Manifest::load(&dir)?)?;
    let task = rt.manifest.task(tname)?.clone();

    // -- 1. calibration (paper §3: forward passes only)
    println!("== 1. calibration: 100 batches x {} ==", rt.manifest.calib.batch);
    let t0 = std::time::Instant::now();
    let hist = eh::ensure_calibration(&mut rt, &task, 100, false)?;
    println!("   {} stats x {} batches ({:.1}s)", hist.len(), hist[0].1.len(),
             t0.elapsed().as_secs_f64());

    // -- 2. scale derivation
    let stats = AggStats::from_history(&hist, &rt.manifest.model, 100.0)?;
    println!("\n== 2. derived scales (layer 0) ==");
    let sc = derive_layer_scales(&stats, 0);
    println!("   SQ:  S_q={:.5}  S_k={:.5}  S_v={:.5}  s_p={:.6}",
             sc.sq_q, sc.sq_k, sc.sq_v, sc.sp);
    let rng = |v: &[f32]| (v.iter().cloned().fold(f32::MAX, f32::min),
                           v.iter().cloned().fold(f32::MIN, f32::max));
    for (name, v) in [("S_attn", &sc.s_attn), ("S_o", &sc.s_o),
                      ("S_a(gelu)", &sc.s_a), ("S_x2", &sc.s_x2)] {
        let (lo, hi) = rng(v);
        println!("   FWQ: {name:10} [{lo:.5}, {hi:.5}] over {} features", v.len());
    }

    // -- 3. fold + quantize (eqs. 20-23, 32)
    println!("\n== 3. fold + column-quantize -> {mode} ==");
    let ckpt = eh::quantize_task(&mut rt, &task, mode, &hist, 100.0, None)?;
    let fp = Container::read_file(&rt.manifest.path(&task.checkpoint))?
        .reordered(&rt.manifest.mode("fp")?.params)?;
    let (mut int8_bytes, mut f32_bytes) = (0usize, 0usize);
    for (_, t) in &ckpt.entries {
        match t.dtype() {
            DType::I8 => int8_bytes += t.nbytes(),
            _ => f32_bytes += t.nbytes(),
        }
    }
    let fp_bytes: usize = fp.entries.iter().map(|(_, t)| t.nbytes()).sum();
    println!("   fp32 checkpoint: {:.2} MB", fp_bytes as f64 / 1e6);
    println!("   {mode} checkpoint: {:.2} MB ({:.2} MB int8 + {:.2} MB f32/scales)",
             (int8_bytes + f32_bytes) as f64 / 1e6,
             int8_bytes as f64 / 1e6, f32_bytes as f64 / 1e6);

    // per-weight reconstruction error (weights that were NOT folded)
    let mut t = Table::new(&["param", "absmax", "scale range", "max |err|/step"]);
    for name in ["L0.fc1.wq", "L0.attn.q.wq"] {
        if let (Some(q), Some(s)) = (ckpt.get(name), ckpt.get(&name.replace(".wq", ".ws"))) {
            let sv = s.as_f32()?;
            let (lo, hi) = rng(sv);
            t.row(vec![
                name.into(),
                format!("{:.3}", sv.iter().zip(q.as_i8()?.chunks(sv.len()))
                        .map(|(s, _)| s * 127.0).fold(0f32, f32::max)),
                format!("[{lo:.5},{hi:.5}]"),
                "<= 0.5 by construction".into(),
            ]);
        }
    }
    t.print();

    // -- 4. end-to-end divergence vs FP on a dev batch
    println!("\n== 4. logit divergence vs FP (first dev batch) ==");
    rt.upload_checkpoint(&task.name, "fp", &fp)?;
    rt.upload_checkpoint(&task.name, mode, &ckpt)?;
    let split = Split::load(&rt.manifest, &task, "dev")?;
    let b = &batches(&split, 16)[0];
    let lf = rt.infer(&task.name, "fp", 16, &b.ids, &b.type_ids, &b.mask)?;
    let lq = rt.infer(&task.name, mode, 16, &b.ids, &b.type_ids, &b.mask)?;
    let (lf, lq) = (lf.as_f32()?, lq.as_f32()?);
    let nl = rt.manifest.model.num_labels;
    let mut max_abs = 0f32;
    let mut agree = 0;
    for row in 0..b.real {
        let (a, b_) = (&lf[row * nl..(row + 1) * nl], &lq[row * nl..(row + 1) * nl]);
        for (x, y) in a.iter().zip(b_) {
            max_abs = max_abs.max((x - y).abs());
        }
        let am = |v: &[f32]| if v[0] >= v[1] { 0 } else { 1 };
        agree += usize::from(am(a) == am(b_));
    }
    println!("   max |logit diff| = {max_abs:.4};  prediction agreement {agree}/{}", b.real);
    println!("\nquantized checkpoint written to checkpoints/{}/hero-{mode}.bin", task.name);
    Ok(())
}
