//! End-to-end serving benchmark (the paper's missing "system performance
//! measurement"): closed-loop load through the coordinator, per mode,
//! A/B-ing the pipelined engine (interned routes + pooled staging +
//! overlapped upload/execute/readback) against the pre-pipeline blocking
//! engine loop — latency percentiles + throughput, written to
//! `BENCH_e2e_serving.json` so the perf trajectory is tracked PR over PR.
//!
//! A second sweep drives three precision *policies* through the typed
//! `RequestSpec` surface (all-INT8, the paper-style FP attention-output
//! fallback, all-FP) and writes per-policy p50/p99 to
//! `BENCH_precision_policy.json`.
//!
//! A third sweep runs the same closed loop against 1 vs N engine
//! replicas behind the load-aware `EnginePool` dispatcher and writes
//! throughput scaling plus per-replica batch counts to
//! `BENCH_replica_scaling.json`.
//!
//! A fourth sweep is the overload experiment (DESIGN.md §5.8): open-loop
//! arrivals at 1x/2x/4x measured capacity against a governable manifest
//! policy, governor off vs on, with per-request deadlines and a bounded
//! admission queue — writing the shed/expired/completed ledger (which
//! must reconcile exactly: admitted = completed + shed + expired) and
//! goodput/p99 per cell to `BENCH_overload.json`.
//!
//! A fifth sweep is the length-aware experiment (DESIGN.md §5.9): the
//! same mixed-length workload driven once padded to the model max
//! client-side (the single-seq baseline — what every request paid before
//! the seq-bucket grid) and once at real lengths (bucketed), writing
//! padded-token volume, padding efficiency, and p50/p99 per cell to
//! `BENCH_seq_buckets.json` — and asserting the >=2x padded-token
//! reduction the grid exists to deliver.
//!
//! Env: ZQH_REQUESTS (default 128), ZQH_TASK (default sst2),
//! ZQH_REPLICAS (default 2 — top of the replica sweep),
//! ZQH_OVERLOAD_ARRIVALS (default 256 — open-loop burst size).

use std::time::Duration;

use zqhero::bench::Table;
use zqhero::coordinator::{Coordinator, GovernorConfig, PolicyRef, ServerConfig};
use zqhero::data::Split;
use zqhero::evalharness as eh;
use zqhero::json::{self, Value};
use zqhero::model::manifest::{Manifest, PolicyDraft};
use zqhero::runtime::Runtime;

struct LoadResult {
    thr_rps: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    mean_batch: f64,
}

fn run_load(
    coord: &Coordinator,
    task: &str,
    policy: &PolicyRef,
    stats_key: &str,
    rows: &[(Vec<i32>, Vec<i32>)],
    requests: usize,
    concurrency: usize,
) -> LoadResult {
    let t0 = std::time::Instant::now();
    // the shared closed-loop driver (also behind `serve-bench`), so the
    // bench trajectory and the CLI smoke measure identical behavior
    let mut lat = zqhero::bench::closed_loop(coord, task, policy, rows, requests, concurrency)
        .expect("closed loop");
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |p: f64| lat[((lat.len() - 1) as f64 * p) as usize] / 1e3;
    let snap = coord.recorder.snapshot();
    LoadResult {
        thr_rps: requests as f64 / wall,
        p50_ms: pick(0.50),
        p95_ms: pick(0.95),
        p99_ms: pick(0.99),
        mean_batch: snap[stats_key].mean_batch_size(),
    }
}

/// Closed-loop in-flight window, also recorded in the JSON report.
const CONCURRENCY: usize = 48;

fn main() {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("e2e_serving: run `make artifacts` first");
        return;
    }
    let requests: usize =
        std::env::var("ZQH_REQUESTS").ok().and_then(|s| s.parse().ok()).unwrap_or(128);
    let tname = std::env::var("ZQH_TASK").unwrap_or_else(|_| "sst2".into());
    let modes = ["fp", "m1", "m2", "m3"];

    // prep quantized checkpoints
    {
        let mut rt = Runtime::new(Manifest::load(&dir).unwrap()).unwrap();
        let task = rt.manifest.task(&tname).unwrap().clone();
        let hist = eh::ensure_calibration(&mut rt, &task, 100, false).unwrap();
        for m in modes.iter().filter(|m| **m != "fp") {
            let rel = task.checkpoint_rel(m);
            if !rt.manifest.path(&rel).exists() {
                eh::quantize_task(&mut rt, &task, m, &hist, 100.0, None).unwrap();
            }
        }
    }
    let man = Manifest::load(&dir).unwrap();
    let task = man.task(&tname).unwrap();
    let split = Split::load(&man, task, "dev").unwrap();
    let rows: Vec<(Vec<i32>, Vec<i32>)> = (0..split.len().min(256))
        .map(|i| {
            let (a, b) = split.row(i);
            (a.to_vec(), b.to_vec())
        })
        .collect();

    println!("\ne2e serving on {tname}: {requests} requests per config\n");
    let mut t = Table::new(&[
        "mode", "engine", "thr req/s", "p50 ms", "p95 ms", "p99 ms", "mean batch",
    ]);
    // baseline first: the blocking loop is the pre-pipeline engine shape
    let mut results: Vec<(String, &str, LoadResult)> = Vec::new();
    for (engine_label, pipeline) in [("blocking", false), ("pipelined", true)] {
        let pairs: Vec<(String, String)> =
            modes.iter().map(|m| (tname.clone(), m.to_string())).collect();
        let coord = Coordinator::start(
            dir.clone(),
            &pairs,
            ServerConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(4),
                queue_cap: 512,
                completion_workers: 4,
                pipeline,
                ..ServerConfig::default()
            },
        )
        .expect("coordinator");
        for m in modes {
            let policy = PolicyRef::Named(m.to_string());
            let r = run_load(&coord, &tname, &policy, m, &rows, requests, CONCURRENCY);
            t.row(vec![
                m.to_string(),
                engine_label.into(),
                format!("{:.1}", r.thr_rps),
                format!("{:.1}", r.p50_ms),
                format!("{:.1}", r.p95_ms),
                format!("{:.1}", r.p99_ms),
                format!("{:.2}", r.mean_batch),
            ]);
            results.push((m.to_string(), engine_label, r));
        }
    }
    t.print();

    // ---- machine-readable trajectory: BENCH_e2e_serving.json
    let mut mode_objs: Vec<(String, Value)> = Vec::new();
    let (mut base_sum, mut pipe_sum, mut n_modes) = (0.0, 0.0, 0);
    for m in modes {
        let base = results.iter().find(|(mm, e, _)| mm.as_str() == m && *e == "blocking");
        let pipe = results.iter().find(|(mm, e, _)| mm.as_str() == m && *e == "pipelined");
        if let (Some((_, _, b)), Some((_, _, p))) = (base, pipe) {
            base_sum += b.thr_rps;
            pipe_sum += p.thr_rps;
            n_modes += 1;
            mode_objs.push((
                m.to_string(),
                json::obj(vec![
                    ("baseline_thr_rps", json::num(b.thr_rps)),
                    ("pipelined_thr_rps", json::num(p.thr_rps)),
                    ("speedup", json::num(p.thr_rps / b.thr_rps.max(1e-9))),
                    ("baseline_p50_ms", json::num(b.p50_ms)),
                    ("pipelined_p50_ms", json::num(p.p50_ms)),
                    ("baseline_p99_ms", json::num(b.p99_ms)),
                    ("pipelined_p99_ms", json::num(p.p99_ms)),
                    ("mean_batch", json::num(p.mean_batch)),
                ]),
            ));
        }
    }
    let overall_speedup = if n_modes > 0 && base_sum > 0.0 { pipe_sum / base_sum } else { 0.0 };
    let report = json::obj(vec![
        ("bench", json::s("e2e_serving")),
        ("task", json::s(&tname)),
        ("requests_per_config", json::num(requests as f64)),
        ("concurrency", json::num(CONCURRENCY as f64)),
        ("baseline_thr_rps_total", json::num(base_sum)),
        ("pipelined_thr_rps_total", json::num(pipe_sum)),
        ("overall_speedup", json::num(overall_speedup)),
        (
            "modes",
            Value::Object(mode_objs.into_iter().collect()),
        ),
    ]);
    let out = json::to_string_pretty(&report);
    match std::fs::write("BENCH_e2e_serving.json", &out) {
        Ok(()) => println!("\nwrote BENCH_e2e_serving.json (overall speedup {overall_speedup:.2}x)"),
        Err(e) => eprintln!("could not write BENCH_e2e_serving.json: {e}"),
    }

    // ---- precision-policy sweep: the typed RequestSpec surface end to
    // end (inline policy -> PolicyId interning -> engine exec selection)
    let policy_cfgs: Vec<(&str, PolicyDraft)> = vec![
        ("all-int8", PolicyDraft::base("m3")),
        (
            // paper-style accuracy recovery: attention output stays FP;
            // no artifact matches, the chain escalates to the nearest
            // mode that is no more quantized than asked (m1)
            "attn-out-fp",
            PolicyDraft::base("m3")
                .with_override("attn_output", "fp")
                .with_fallback("m2")
                .with_fallback("m1")
                .with_fallback("fp"),
        ),
        ("all-fp", PolicyDraft::base("fp")),
    ];
    let exec_modes: Vec<String> = policy_cfgs
        .iter()
        .map(|(name, d)| {
            let spec = man.resolve_policy(name, d).expect("policy resolves");
            man.mode_name(spec.exec_mode).to_string()
        })
        .collect();
    let pairs: Vec<(String, String)> =
        exec_modes.iter().map(|m| (tname.clone(), m.clone())).collect();
    let coord = Coordinator::start(
        dir.clone(),
        &pairs,
        ServerConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(4),
            queue_cap: 512,
            completion_workers: 4,
            ..ServerConfig::default()
        },
    )
    .expect("policy coordinator");

    println!("\nprecision-policy sweep on {tname}: {requests} requests per policy\n");
    let mut pt = Table::new(&["policy", "exec mode", "thr req/s", "p50 ms", "p99 ms", "mean batch"]);
    let mut policy_objs: Vec<(String, Value)> = Vec::new();
    for ((name, draft), exec) in policy_cfgs.iter().zip(&exec_modes) {
        // stats land on the interned policy slot: the identical manifest
        // policy if one exists, else the exec mode's uniform slot
        let interned = man.intern_inline_policy(draft).expect("interns");
        let stats_key = man.policy_name(interned).to_string();
        let policy = PolicyRef::Inline(draft.clone());
        let r = run_load(&coord, &tname, &policy, &stats_key, &rows, requests, CONCURRENCY);
        pt.row(vec![
            name.to_string(),
            exec.clone(),
            format!("{:.1}", r.thr_rps),
            format!("{:.1}", r.p50_ms),
            format!("{:.1}", r.p99_ms),
            format!("{:.2}", r.mean_batch),
        ]);
        policy_objs.push((
            name.to_string(),
            json::obj(vec![
                ("exec_mode", json::s(exec)),
                ("thr_rps", json::num(r.thr_rps)),
                ("p50_ms", json::num(r.p50_ms)),
                ("p99_ms", json::num(r.p99_ms)),
                ("mean_batch", json::num(r.mean_batch)),
            ]),
        ));
    }
    pt.print();

    let policy_report = json::obj(vec![
        ("bench", json::s("precision_policy")),
        ("task", json::s(&tname)),
        ("requests_per_policy", json::num(requests as f64)),
        ("concurrency", json::num(CONCURRENCY as f64)),
        ("policies", Value::Object(policy_objs)),
    ]);
    match std::fs::write("BENCH_precision_policy.json", json::to_string_pretty(&policy_report)) {
        Ok(()) => println!("\nwrote BENCH_precision_policy.json"),
        Err(e) => eprintln!("could not write BENCH_precision_policy.json: {e}"),
    }

    // ---- replica scaling sweep: the same closed loop against 1 vs N
    // engine replicas behind the load-aware dispatcher (EnginePool).
    // Two routes (fp + m3) keep two groups alive so per-group pinning
    // and migration are exercised, not just raw fan-out.
    let n_replicas: usize =
        std::env::var("ZQH_REPLICAS").ok().and_then(|s| s.parse().ok()).unwrap_or(2);
    let sweep: Vec<usize> = if n_replicas > 1 { vec![1, n_replicas] } else { vec![1] };
    let scale_modes = ["fp", "m3"];
    println!("\nreplica scaling on {tname}: {requests} requests per mode per config\n");
    let mut rt_tab = Table::new(&[
        "replicas", "thr req/s (total)", "p50 ms (m3)", "p99 ms (m3)", "per-replica batches",
    ]);
    let mut cfg_objs: Vec<(String, Value)> = Vec::new();
    let mut thr_by_cfg: Vec<(usize, f64)> = Vec::new();
    for &n in &sweep {
        let pairs: Vec<(String, String)> =
            scale_modes.iter().map(|m| (tname.clone(), m.to_string())).collect();
        let coord = Coordinator::start(
            dir.clone(),
            &pairs,
            ServerConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(4),
                queue_cap: 512,
                completion_workers: 4,
                replicas: n,
                ..ServerConfig::default()
            },
        )
        .expect("replica coordinator");
        // drive both route groups concurrently: a single closed loop
        // keeps only one group in flight, and per-group pinning would
        // park every batch on one replica — concurrent groups are the
        // load the pool exists to spread
        let results: Vec<(&str, LoadResult)> = std::thread::scope(|s| {
            let handles: Vec<_> = scale_modes
                .iter()
                .map(|m| {
                    let coord = &coord;
                    let rows = &rows;
                    let tname = tname.as_str();
                    s.spawn(move || {
                        let policy = PolicyRef::Named(m.to_string());
                        (*m, run_load(coord, tname, &policy, m, rows, requests, CONCURRENCY))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("load thread")).collect()
        });
        let mut thr_total = 0.0;
        let mut m3_result: Option<LoadResult> = None;
        for (m, r) in results {
            thr_total += r.thr_rps;
            if m == "m3" {
                m3_result = Some(r);
            }
        }
        let m3 = m3_result.expect("m3 swept");
        let reps = coord.recorder.replica_snapshot();
        let batches: Vec<u64> = reps.iter().map(|r| r.batches).collect();
        let total_batches: u64 = batches.iter().sum();
        rt_tab.row(vec![
            n.to_string(),
            format!("{thr_total:.1}"),
            format!("{:.1}", m3.p50_ms),
            format!("{:.1}", m3.p99_ms),
            format!("{batches:?}"),
        ]);
        cfg_objs.push((
            n.to_string(),
            json::obj(vec![
                ("thr_rps_total", json::num(thr_total)),
                ("m3_p50_ms", json::num(m3.p50_ms)),
                ("m3_p99_ms", json::num(m3.p99_ms)),
                ("total_batches", json::num(total_batches as f64)),
                (
                    "per_replica_batches",
                    Value::Array(batches.iter().map(|b| json::num(*b as f64)).collect()),
                ),
            ]),
        ));
        thr_by_cfg.push((n, thr_total));
    }
    rt_tab.print();

    let base_thr = thr_by_cfg.first().map(|(_, t)| *t).unwrap_or(0.0);
    let top_thr = thr_by_cfg.last().map(|(_, t)| *t).unwrap_or(0.0);
    let scaling = if base_thr > 0.0 { top_thr / base_thr } else { 0.0 };
    let scale_report = json::obj(vec![
        ("bench", json::s("replica_scaling")),
        ("task", json::s(&tname)),
        ("requests_per_config", json::num(requests as f64)),
        ("concurrency", json::num(CONCURRENCY as f64)),
        ("max_replicas", json::num(n_replicas as f64)),
        ("configs", Value::Object(cfg_objs)),
        ("scaling_vs_single", json::num(scaling)),
    ]);
    match std::fs::write("BENCH_replica_scaling.json", json::to_string_pretty(&scale_report)) {
        Ok(()) => println!("\nwrote BENCH_replica_scaling.json (scaling {scaling:.2}x)"),
        Err(e) => eprintln!("could not write BENCH_replica_scaling.json: {e}"),
    }

    overload_sweep(&dir, &man, &tname, &rows, requests);
    // last: this sweep asserts the >=2x padded-token reduction, so a
    // padding regression must not suppress the other trajectory files
    seq_bucket_sweep(&dir, &man, &tname, &rows, requests);
    println!("(CPU PJRT testbed; A100 projections in hw_perf_model)");
}

/// Mixed-length workload sweep (DESIGN.md §5.9) -> BENCH_seq_buckets.json.
///
/// The workload is the dev rows at their *real* lengths (PAD tail
/// trimmed), with every 4th row kept at the model max so the top bucket
/// stays exercised.  The single-seq baseline drives the identical
/// logical workload padded to the model max client-side — exactly what
/// every request paid before the seq-bucket grid.  Cells run on fresh
/// coordinators so the recorders' padding ledgers are comparable.
/// Asserts the headline claim: bucketed batching cuts total padded-token
/// volume by at least 2x on this workload.
fn seq_bucket_sweep(
    dir: &std::path::Path,
    man: &Manifest,
    tname: &str,
    rows: &[(Vec<i32>, Vec<i32>)],
    requests: usize,
) {
    if man.num_seq_buckets() == 1 {
        println!(
            "\nseq-bucket sweep skipped: single-seq manifest (format_version 2 — regenerate \
             artifacts for the (seq, batch) grid)"
        );
        return;
    }
    let mixed = zqhero::data::mixed_length_workload(rows);

    let mode = "m3";
    let pairs = vec![(tname.to_string(), mode.to_string())];
    println!(
        "\nseq-bucket sweep on ({tname},{mode}): {requests} requests per cell, \
         seq buckets {:?}\n",
        man.seq_buckets
    );
    let mut t = Table::new(&[
        "cell", "thr req/s", "p50 ms", "p99 ms", "padded tokens", "real tokens", "pad eff",
    ]);
    let mut cells: Vec<(String, Value)> = Vec::new();
    let mut volume: Vec<(&str, u64)> = Vec::new();
    for (label, payload) in [("single_seq", rows), ("bucketed", &mixed[..])] {
        let coord = Coordinator::start(
            dir.to_path_buf(),
            &pairs,
            ServerConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(4),
                queue_cap: 512,
                completion_workers: 4,
                ..ServerConfig::default()
            },
        )
        .expect("seq-bucket coordinator");
        let policy = PolicyRef::Named(mode.to_string());
        let r = run_load(&coord, tname, &policy, mode, payload, requests, CONCURRENCY);
        // one route per cell, so the snapshot totals are this policy's —
        // summed through the same helper the serve-bench smoke uses, so
        // the two BENCH files' token semantics cannot drift
        let (real, padded) = zqhero::bench::padding_totals(&coord.recorder.snapshot());
        let efficiency = real as f64 / padded.max(1) as f64;
        t.row(vec![
            label.to_string(),
            format!("{:.1}", r.thr_rps),
            format!("{:.1}", r.p50_ms),
            format!("{:.1}", r.p99_ms),
            padded.to_string(),
            real.to_string(),
            format!("{:.0}%", 100.0 * efficiency),
        ]);
        cells.push((
            label.to_string(),
            json::obj(vec![
                ("thr_rps", json::num(r.thr_rps)),
                ("p50_ms", json::num(r.p50_ms)),
                ("p99_ms", json::num(r.p99_ms)),
                ("padded_tokens", json::num(padded as f64)),
                ("real_tokens", json::num(real as f64)),
                ("pad_efficiency", json::num(efficiency)),
            ]),
        ));
        volume.push((label, padded));
    }
    t.print();

    let base = volume.iter().find(|(l, _)| *l == "single_seq").map(|(_, v)| *v).unwrap_or(0);
    let bucketed = volume.iter().find(|(l, _)| *l == "bucketed").map(|(_, v)| *v).unwrap_or(0);
    let reduction = base as f64 / bucketed.max(1) as f64;
    let report = json::obj(vec![
        ("bench", json::s("seq_buckets")),
        ("task", json::s(tname)),
        ("mode", json::s(mode)),
        ("requests_per_cell", json::num(requests as f64)),
        ("concurrency", json::num(CONCURRENCY as f64)),
        (
            "seq_buckets",
            Value::Array(man.seq_buckets.iter().map(|s| json::num(*s as f64)).collect()),
        ),
        ("cells", Value::Object(cells)),
        ("padded_token_reduction", json::num(reduction)),
        ("meets_2x", Value::Bool(reduction >= 2.0)),
    ]);
    // write the trajectory point *before* gating on it: a regressed run
    // must still leave its per-cell diagnostics on disk
    match std::fs::write("BENCH_seq_buckets.json", json::to_string_pretty(&report)) {
        Ok(()) => {
            println!("\nwrote BENCH_seq_buckets.json (padded-token reduction {reduction:.2}x)")
        }
        Err(e) => eprintln!("could not write BENCH_seq_buckets.json: {e}"),
    }
    // the acceptance bar: mixed-length traffic must stop paying the
    // model-max memory tax — anything under 2x means the grid is not
    // actually routing short requests to short executables
    assert!(
        reduction >= 2.0,
        "bucketed batching must cut padded-token volume >=2x vs the single-seq baseline \
         (got {reduction:.2}x: {base} -> {bucketed}; see BENCH_seq_buckets.json)"
    );
}

/// Run one open-loop cell through the shared driver
/// (`zqhero::bench::open_loop_burst` — the same code path as
/// `serve-bench --overload`) and reconcile the client-side ledger
/// against the recorder's (fresh coordinator per cell); returns the
/// report plus the recorder's governed count.
fn open_loop(
    coord: &Coordinator,
    task: &str,
    policy: &str,
    rows: &[(Vec<i32>, Vec<i32>)],
    arrivals: usize,
    rate: f64,
    deadline: Duration,
) -> (zqhero::bench::OpenLoopReport, u64) {
    let r = zqhero::bench::open_loop_burst(coord, task, policy, rows, arrivals, rate, deadline)
        .expect("open-loop burst");
    assert!(r.reconciles(), "client overload ledger must reconcile: {r:?}");
    let snap = coord.recorder.snapshot();
    let s = &snap[policy];
    assert_eq!(s.shed as usize, r.shed, "recorder shed count");
    assert_eq!(s.expired as usize, r.expired, "recorder expired count");
    assert_eq!(s.completed as usize, r.completed, "recorder completed count");
    // NB vocabulary: the ledger's "admitted" counts *offered* arrivals
    // (shed included); the recorder's `requests` holds only those that
    // entered the queue
    assert_eq!(s.requests as usize, r.admitted - r.shed, "recorder terminal count");
    (r, s.governed)
}

/// Open-loop overload at 1x/2x/4x measured capacity, governor off vs on,
/// against a governable manifest policy -> BENCH_overload.json.
fn overload_sweep(
    dir: &std::path::Path,
    man: &Manifest,
    tname: &str,
    rows: &[(Vec<i32>, Vec<i32>)],
    requests: usize,
) {
    // a policy whose downgrade chain is non-empty (the python manifest
    // writer ships attn-out-fp: base m3, fallback [m2, m1, fp], exec m1,
    // chain [m2, m3]); without one the governor has nothing to govern
    let governed = man.policy_order.iter().find(|n| {
        man.policy_id(n.as_str())
            .map(|p| !man.downgrade_chain(p).is_empty())
            .unwrap_or(false)
    });
    let Some(policy) = governed else {
        println!("\noverload sweep skipped: no manifest policy has a degradation chain");
        return;
    };
    let arrivals: usize = std::env::var("ZQH_OVERLOAD_ARRIVALS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(256);
    // a queue bound well under the burst size so backpressure and the
    // governor watermarks are actually exercised
    let queue_cap = 64usize;
    let deadline = Duration::from_millis(250);
    let config = |governor: bool| ServerConfig {
        max_batch: 16,
        max_wait: Duration::from_millis(4),
        queue_cap,
        completion_workers: 4,
        governor: governor.then(|| GovernorConfig::for_queue(queue_cap)),
        ..ServerConfig::default()
    };
    let pairs = vec![(tname.to_string(), policy.clone())];

    // capacity: short closed loop (run_load) on a governor-off coordinator
    let capacity_rps = {
        let coord = Coordinator::start(dir.to_path_buf(), &pairs, config(false))
            .expect("overload calibration coordinator");
        let r = run_load(
            &coord,
            tname,
            &PolicyRef::Named(policy.clone()),
            policy,
            rows,
            requests.max(64),
            16,
        );
        r.thr_rps
    };

    println!(
        "\noverload sweep on ({tname},{policy}): {arrivals} open-loop arrivals per cell, \
         capacity ~{capacity_rps:.1} req/s, deadline {}ms, queue cap {queue_cap}\n",
        deadline.as_millis()
    );
    let mut t = Table::new(&[
        "rate", "governor", "admitted", "completed", "shed", "expired", "governed",
        "goodput req/s", "p50 ms", "p99 ms",
    ]);
    let mut cells: Vec<(String, Value)> = Vec::new();
    let mut gain_2x: (f64, f64) = (0.0, 0.0); // (off, on) goodput at 2x
    let mut p99_2x: (f64, f64) = (0.0, 0.0);
    for gov in [false, true] {
        for mult in [1.0f64, 2.0, 4.0] {
            // fresh coordinator per cell: each run starts undegraded with
            // an empty queue, so cells are comparable
            let coord = Coordinator::start(dir.to_path_buf(), &pairs, config(gov))
                .expect("overload coordinator");
            let (p, governed) = open_loop(
                &coord,
                tname,
                policy,
                rows,
                arrivals,
                capacity_rps * mult,
                deadline,
            );
            let label = format!("{mult}x_{}", if gov { "on" } else { "off" });
            t.row(vec![
                format!("{mult}x"),
                if gov { "on" } else { "off" }.into(),
                p.admitted.to_string(),
                p.completed.to_string(),
                p.shed.to_string(),
                p.expired.to_string(),
                governed.to_string(),
                format!("{:.1}", p.goodput_rps()),
                format!("{:.1}", p.p50_ms),
                format!("{:.1}", p.p99_ms),
            ]);
            if mult == 2.0 {
                if gov {
                    gain_2x.1 = p.goodput_rps();
                    p99_2x.1 = p.p99_ms;
                } else {
                    gain_2x.0 = p.goodput_rps();
                    p99_2x.0 = p.p99_ms;
                }
            }
            cells.push((
                label,
                json::obj(vec![
                    ("admitted", json::num(p.admitted as f64)),
                    ("completed", json::num(p.completed as f64)),
                    ("shed", json::num(p.shed as f64)),
                    ("expired", json::num(p.expired as f64)),
                    ("governed", json::num(governed as f64)),
                    ("goodput_rps", json::num(p.goodput_rps())),
                    ("p50_ms", json::num(p.p50_ms)),
                    ("p99_ms", json::num(p.p99_ms)),
                ]),
            ));
        }
    }
    t.print();

    let goodput_gain = gain_2x.1 / gain_2x.0.max(1e-9);
    if goodput_gain < 1.0 {
        println!("WARNING: governor-on goodput below governor-off at 2x ({goodput_gain:.2}x)");
    }
    let report = json::obj(vec![
        ("bench", json::s("overload")),
        ("task", json::s(tname)),
        ("policy", json::s(policy)),
        ("arrivals_per_cell", json::num(arrivals as f64)),
        ("capacity_rps", json::num(capacity_rps)),
        ("deadline_ms", json::num(deadline.as_millis() as f64)),
        ("queue_cap", json::num(queue_cap as f64)),
        ("cells", Value::Object(cells)),
        ("goodput_gain_2x_governor", json::num(goodput_gain)),
        ("p99_2x_governor_off_ms", json::num(p99_2x.0)),
        ("p99_2x_governor_on_ms", json::num(p99_2x.1)),
    ]);
    match std::fs::write("BENCH_overload.json", json::to_string_pretty(&report)) {
        Ok(()) => {
            println!("\nwrote BENCH_overload.json (2x governor goodput gain {goodput_gain:.2}x)")
        }
        Err(e) => eprintln!("could not write BENCH_overload.json: {e}"),
    }
}
