//! Manifest format_version 3 grammar (DESIGN.md §5.9), pinned WITHOUT a
//! generated artifacts dir via `Manifest::from_json_str`:
//!
//! * `seq_buckets` absent (format_version 2) ⇒ the single-bucket axis
//!   `[seq]`, and bare `"bN"` artifact keys mean `(seq, N)` — a v2
//!   manifest loads and routes identically to before the grid existed;
//! * grid keys `"sSbB"` address (seq bucket, batch bucket) cells;
//! * the grammar's error paths (empty / non-ascending / top-mismatched
//!   `seq_buckets`, malformed or off-grid artifact keys) fail at load,
//!   never at admission;
//! * `ServerConfig::max_batch` is validated against the manifest's
//!   largest batch bucket at startup with a typed `ConfigError` — the
//!   silent `bucket_for` clamp is not reachable from serving config.

use std::path::Path;

use zqhero::coordinator::{ConfigError, Coordinator, ServerConfig};
use zqhero::model::manifest::Manifest;

/// Minimal two-mode manifest; `seq_buckets_line` and the fp mode's
/// `artifacts` object are spliced in by each test.
fn manifest_src(seq_buckets_line: &str, fp_artifacts: &str) -> String {
    format!(
        r#"{{
  "model": {{"vocab_size": 16, "hidden": 8, "layers": 1, "heads": 2,
            "ffn": 16, "max_seq": 16, "type_vocab": 2, "num_labels": 2,
            "ln_eps": 1e-12}},
  "seq": 16,
  {seq_buckets_line}
  "buckets": [1, 4],
  "modes": {{
    "fp": {{"switches": {{"embedding": false, "qkv": false, "attn": false,
                        "attn_output": false, "fc1": false, "fc2": false}},
           "params": [], "artifacts": {fp_artifacts}}},
    "m3": {{"switches": {{"embedding": true, "qkv": true, "attn": true,
                        "attn_output": true, "fc1": true, "fc2": true}},
           "params": [], "artifacts": {{}}}}
  }},
  "calib": {{"artifact": "c.hlo", "batch": 4, "params": [], "stats": []}},
  "tasks": {{
    "sst2": {{"classes": 2, "metrics": ["acc"], "splits": {{"dev": "d.bin"}},
             "checkpoint": "checkpoints/sst2/fp32.bin"}}
  }}
}}"#
    )
}

fn load(seq_buckets_line: &str, fp_artifacts: &str) -> anyhow::Result<Manifest> {
    Manifest::from_json_str(&manifest_src(seq_buckets_line, fp_artifacts), Path::new("unused"))
}

#[test]
fn absent_seq_buckets_falls_back_to_single_seq_axis() {
    // format_version 2 shape: no seq_buckets key, bare "bN" artifact keys
    let man = load("", r#"{"b1": "models/fp/b1.hlo.txt", "b4": "models/fp/b4.hlo.txt"}"#)
        .unwrap();
    assert_eq!(man.seq_buckets, vec![16], "absent ⇒ [seq]");
    assert_eq!(man.num_seq_buckets(), 1);
    // every admissible length lands in the one full-seq class
    for n in [1, 7, 16] {
        assert_eq!(man.seq_bucket_for(n), 16);
    }
    // legacy keys mean (seq, batch): the grid-shaped tables still route
    let fp = man.mode("fp").unwrap();
    assert_eq!(fp.artifacts.get(&(16, 1)).map(String::as_str), Some("models/fp/b1.hlo.txt"));
    assert_eq!(fp.artifacts.get(&(16, 4)).map(String::as_str), Some("models/fp/b4.hlo.txt"));
    assert!(fp.artifacts.get(&(8, 1)).is_none());
}

#[test]
fn grid_keys_round_trip_and_mix_with_legacy() {
    let man = load(
        r#""seq_buckets": [8, 16],"#,
        r#"{"s8b1": "models/fp/s8_b1.hlo.txt",
            "s16b4": "models/fp/s16_b4.hlo.txt",
            "b1": "models/fp/b1.hlo.txt"}"#,
    )
    .unwrap();
    assert_eq!(man.seq_buckets, vec![8, 16]);
    assert_eq!(man.seq_bucket_for(3), 8);
    assert_eq!(man.seq_bucket_for(9), 16);
    assert_eq!(man.seq_bucket_index(8).unwrap(), 0);
    assert!(man.seq_bucket_index(9).is_err());
    let fp = man.mode("fp").unwrap();
    assert_eq!(
        fp.artifacts.get(&(8, 1)).map(String::as_str),
        Some("models/fp/s8_b1.hlo.txt")
    );
    assert_eq!(
        fp.artifacts.get(&(16, 4)).map(String::as_str),
        Some("models/fp/s16_b4.hlo.txt")
    );
    // a bare legacy key inside a v3 manifest still pins the full seq
    assert_eq!(fp.artifacts.get(&(16, 1)).map(String::as_str), Some("models/fp/b1.hlo.txt"));
}

#[test]
fn seq_buckets_grammar_errors_fail_at_load() {
    // empty
    let err = format!("{:#}", load(r#""seq_buckets": [],"#, "{}").unwrap_err());
    assert!(err.contains("must not be empty"), "{err}");
    // not strictly ascending
    let err = format!("{:#}", load(r#""seq_buckets": [16, 8],"#, "{}").unwrap_err());
    assert!(err.contains("strictly ascending"), "{err}");
    let err = format!("{:#}", load(r#""seq_buckets": [8, 8, 16],"#, "{}").unwrap_err());
    assert!(err.contains("strictly ascending"), "{err}");
    // top bucket must equal seq, or an admissible request could fit no cell
    let err = format!("{:#}", load(r#""seq_buckets": [4, 8],"#, "{}").unwrap_err());
    assert!(err.contains("largest seq bucket") && err.contains("16"), "{err}");
    // non-numeric entry
    assert!(load(r#""seq_buckets": [8, "x"],"#, "{}").is_err());
}

#[test]
fn artifact_key_errors_fail_at_load() {
    // malformed grid key (no batch half)
    let err = format!(
        "{:#}",
        load(r#""seq_buckets": [8, 16],"#, r#"{"s8": "x.hlo"}"#).unwrap_err()
    );
    assert!(err.contains("bad artifact key") || err.contains("s8"), "{err}");
    // seq not declared in seq_buckets
    let err = format!(
        "{:#}",
        load(r#""seq_buckets": [8, 16],"#, r#"{"s32b1": "x.hlo"}"#).unwrap_err()
    );
    assert!(err.contains("not in seq_buckets"), "{err}");
    // batch not declared in buckets (a typo'd key must fail at load, not
    // later as a missing-cell error at replica startup)
    let err = format!(
        "{:#}",
        load(r#""seq_buckets": [8, 16],"#, r#"{"s16b3": "x.hlo"}"#).unwrap_err()
    );
    assert!(err.contains("not in buckets"), "{err}");
    let err = format!("{:#}", load("", r#"{"b3": "x.hlo"}"#).unwrap_err());
    assert!(err.contains("not in buckets"), "{err}");
    // a legacy "bN" and a grid "sSbN" key naming the same cell must not
    // silently last-wins between two conflicting artifacts
    let err = format!(
        "{:#}",
        load(r#""seq_buckets": [8, 16],"#, r#"{"b4": "x.hlo", "s16b4": "y.hlo"}"#).unwrap_err()
    );
    assert!(err.contains("duplicate cell"), "{err}");
}

#[test]
fn batch_buckets_must_be_ascending() {
    // bucket_for's first-fit scan and the max_batch validation both read
    // buckets.last() as the largest; an unordered list must fail at load
    let src = manifest_src("", "{}").replace(r#""buckets": [1, 4]"#, r#""buckets": [4, 1]"#);
    let err = format!(
        "{:#}",
        Manifest::from_json_str(&src, Path::new("unused")).unwrap_err()
    );
    assert!(err.contains("buckets must be strictly ascending"), "{err}");
    // legacy key maps to (seq, N), which is always on the axis — fine
    assert!(load(r#""seq_buckets": [8, 16],"#, r#"{"b1": "x.hlo"}"#).is_ok());
    // plain garbage key
    assert!(load("", r#"{"q9": "x.hlo"}"#).is_err());
}

/// The `--max-batch` satellite: startup must refuse a batch size the
/// manifest cannot execute, with a typed error — `bucket_for`'s silent
/// clamp to the largest bucket is for cold paths only.  Runs without
/// generated artifacts: validation fires before any checkpoint I/O, so a
/// manifest.json written to a temp dir is enough.
#[test]
fn max_batch_validated_against_largest_bucket_at_startup() {
    let dir = std::env::temp_dir().join(format!("zqh-manifest-format-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), manifest_src("", "{}")).unwrap();
    let routes = vec![("sst2".to_string(), "fp".to_string())];

    // over the largest bucket (4): typed refusal naming both numbers
    let err = Coordinator::start(
        dir.clone(),
        &routes,
        ServerConfig { max_batch: 99, ..ServerConfig::default() },
    )
    .unwrap_err();
    match err.downcast_ref::<ConfigError>() {
        Some(ConfigError::MaxBatchExceedsBuckets { max_batch, largest_bucket }) => {
            assert_eq!((*max_batch, *largest_bucket), (99, 4));
        }
        other => panic!("expected MaxBatchExceedsBuckets, got {other:?} ({err:#})"),
    }
    assert!(err.to_string().contains("max_batch 99"), "{err}");

    // zero can never form a batch
    let err = Coordinator::start(
        dir.clone(),
        &routes,
        ServerConfig { max_batch: 0, ..ServerConfig::default() },
    )
    .unwrap_err();
    assert!(matches!(err.downcast_ref::<ConfigError>(), Some(ConfigError::ZeroMaxBatch)));

    // a bucket-sized max_batch passes config validation and fails later,
    // on the missing checkpoint — proving the gate is the config, not
    // some broader startup failure
    let err = Coordinator::start(
        dir.clone(),
        &routes,
        ServerConfig { max_batch: 4, ..ServerConfig::default() },
    )
    .unwrap_err();
    assert!(err.downcast_ref::<ConfigError>().is_none());
    assert!(err.to_string().contains("checkpoint"), "{err:#}");

    let _ = std::fs::remove_dir_all(&dir);
}
