//! Pooled host staging buffers for batch assembly (DESIGN.md §5.3).
//!
//! Every admitted batch needs three host arrays — `ids`, `type_ids`,
//! `mask`, each `[bucket * seq]` — that exist only long enough to be
//! copied into device buffers.  Allocating them per batch puts the
//! allocator on the steady-state path; instead the batcher thread checks
//! a `StagingBuf` out of a per-bucket shelf, fills it in place, and the
//! engine thread returns it to the shelf right after the host→device
//! upload.  Shelves are bounded so a burst cannot pin unbounded memory:
//! overflow buffers are simply dropped and the shelf refills on demand.

use std::sync::Mutex;

use crate::data::PAD;

/// One reusable host-side batch: `bucket * seq` token ids / type ids and
/// the derived attention mask.  `real` tracks how many rows were filled
/// before padding.
#[derive(Debug)]
pub struct StagingBuf {
    pub bucket: usize,
    pub seq: usize,
    pub real: usize,
    pub ids: Vec<i32>,
    pub type_ids: Vec<i32>,
    pub mask: Vec<f32>,
}

impl StagingBuf {
    pub fn new(bucket: usize, seq: usize) -> Self {
        StagingBuf {
            bucket,
            seq,
            real: 0,
            ids: Vec::with_capacity(bucket * seq),
            type_ids: Vec::with_capacity(bucket * seq),
            mask: Vec::with_capacity(bucket * seq),
        }
    }

    /// Wrap caller-owned arrays (blocking/CLI path, no pool involved).
    /// `mask` is recomputed to keep one definition of padding semantics.
    /// `real` is the number of rows the caller actually provided
    /// (`ids.len() / seq`, rounded up for a partial final row, capped at
    /// the bucket) — hardcoding `real = bucket` overstated occupancy in
    /// blocking-path timings and `batch_real` reporting whenever fewer
    /// rows were passed.
    pub fn from_parts(bucket: usize, seq: usize, ids: Vec<i32>, type_ids: Vec<i32>) -> Self {
        let real = ids.len().div_ceil(seq.max(1)).min(bucket);
        let mut buf = StagingBuf { bucket, seq, real, ids, type_ids, mask: Vec::new() };
        buf.ids.resize(bucket * seq, PAD);
        buf.type_ids.resize(bucket * seq, 0);
        buf.mask = buf.ids.iter().map(|t| if *t == PAD { 0.0 } else { 1.0 }).collect();
        buf
    }

    /// Clear contents, keeping capacity (called on checkout).
    fn reset(&mut self, bucket: usize, seq: usize) {
        self.bucket = bucket;
        self.seq = seq;
        self.real = 0;
        self.ids.clear();
        self.type_ids.clear();
        self.mask.clear();
    }

    /// Append one request row (`seq` tokens each).
    pub fn push_row(&mut self, ids: &[i32], type_ids: &[i32]) {
        debug_assert_eq!(ids.len(), self.seq);
        debug_assert_eq!(type_ids.len(), self.seq);
        self.ids.extend_from_slice(ids);
        self.type_ids.extend_from_slice(type_ids);
        self.real += 1;
    }

    /// Pad to the bucket and derive the attention mask in one pass.
    pub fn finish(&mut self) {
        let n = self.bucket * self.seq;
        self.ids.resize(n, PAD);
        self.type_ids.resize(n, 0);
        self.mask.clear();
        self.mask.extend(self.ids.iter().map(|t| if *t == PAD { 0.0 } else { 1.0 }));
    }
}

/// Bounded per-bucket free lists of `StagingBuf`s, shared between the
/// batcher thread (checkout + fill) and the engine thread (return after
/// upload).  Lock scope is a `Vec` push/pop — nanoseconds next to the
/// memcpy the buffer exists for.
pub struct StagingPool {
    buckets: Vec<usize>,
    seq: usize,
    per_bucket_cap: usize,
    shelves: Vec<Mutex<Vec<StagingBuf>>>,
}

impl StagingPool {
    pub fn new(buckets: &[usize], seq: usize, per_bucket_cap: usize) -> Self {
        StagingPool {
            buckets: buckets.to_vec(),
            seq,
            per_bucket_cap: per_bucket_cap.max(1),
            shelves: buckets.iter().map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    fn shelf_index(&self, bucket: usize) -> Option<usize> {
        self.buckets.iter().position(|b| *b == bucket)
    }

    /// Check out a cleared buffer for `bucket`, reusing capacity when a
    /// recycled one is on the shelf.
    pub fn take(&self, bucket: usize) -> StagingBuf {
        if let Some(i) = self.shelf_index(bucket) {
            if let Some(mut buf) = self.shelves[i].lock().expect("staging shelf").pop() {
                buf.reset(bucket, self.seq);
                return buf;
            }
        }
        StagingBuf::new(bucket, self.seq)
    }

    /// Return a buffer after upload; dropped silently when the shelf is
    /// full or the bucket is foreign (blocking-path buffers).
    pub fn put(&self, buf: StagingBuf) {
        if let Some(i) = self.shelf_index(buf.bucket) {
            let mut shelf = self.shelves[i].lock().expect("staging shelf");
            if shelf.len() < self.per_bucket_cap {
                shelf.push(buf);
            }
        }
    }

    /// Buffers currently resting on shelves (tests / introspection).
    pub fn pooled(&self) -> usize {
        self.shelves.iter().map(|s| s.lock().expect("staging shelf").len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_pads_and_masks() {
        let mut b = StagingBuf::new(2, 4);
        b.push_row(&[5, 6, 0, 0], &[0, 0, 0, 0]);
        b.finish();
        assert_eq!(b.real, 1);
        assert_eq!(b.ids, vec![5, 6, 0, 0, 0, 0, 0, 0]);
        assert_eq!(b.type_ids.len(), 8);
        assert_eq!(b.mask, vec![1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn pool_recycles_capacity() {
        let pool = StagingPool::new(&[1, 4], 4, 2);
        let mut a = pool.take(4);
        a.push_row(&[1, 2, 3, 4], &[0; 4]);
        a.finish();
        let cap_before = a.ids.capacity();
        pool.put(a);
        assert_eq!(pool.pooled(), 1);
        let b = pool.take(4);
        assert_eq!(pool.pooled(), 0);
        assert_eq!(b.real, 0);
        assert!(b.ids.is_empty());
        assert!(b.ids.capacity() >= cap_before.min(16));
    }

    #[test]
    fn pool_bounds_and_tolerates_foreign_buckets() {
        let pool = StagingPool::new(&[2], 2, 1);
        pool.put(StagingBuf::new(2, 2));
        pool.put(StagingBuf::new(2, 2)); // over cap: dropped
        assert_eq!(pool.pooled(), 1);
        pool.put(StagingBuf::new(7, 2)); // unknown bucket: dropped
        assert_eq!(pool.pooled(), 1);
    }

    #[test]
    fn from_parts_matches_fill_semantics() {
        let b = StagingBuf::from_parts(2, 3, vec![9, 0, 9], vec![1, 1, 1]);
        assert_eq!(b.ids, vec![9, 0, 9, 0, 0, 0]);
        assert_eq!(b.mask, vec![1.0, 0.0, 1.0, 0.0, 0.0, 0.0]);
        // one row of tokens was provided: real reports 1, not the bucket
        assert_eq!(b.real, 1);
    }

    #[test]
    fn from_parts_derives_real_from_rows_provided() {
        // full bucket: unchanged semantics
        let b = StagingBuf::from_parts(2, 3, vec![1; 6], vec![0; 6]);
        assert_eq!(b.real, 2);
        // partial final row rounds up, and real never exceeds the bucket
        let b = StagingBuf::from_parts(4, 3, vec![1; 4], vec![0; 4]);
        assert_eq!(b.real, 2);
        let b = StagingBuf::from_parts(2, 3, vec![1; 9], vec![0; 9]);
        assert_eq!(b.real, 2);
        // degenerate inputs stay safe
        let b = StagingBuf::from_parts(2, 0, vec![], vec![]);
        assert_eq!(b.real, 0);
    }
}
