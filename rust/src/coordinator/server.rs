//! The serving coordinator: bounded admission queue -> dynamic batcher
//! thread -> engine (PJRT) replica pool -> completion workers.  This is the
//! "end-to-end system" the paper leaves as future work: batched W8A8
//! inference with per-request precision *policies* and zero Python
//! anywhere.
//!
//! Hot-path discipline (DESIGN.md §5-§6): `RequestSpec` policy references
//! are interned to `TaskId`/`PolicyId` at admission; requests keep their
//! real token length and batch per sequence-length class (§5.9) so short
//! requests never pay max-seq memory traffic; batch assembly writes into
//! pooled staging buffers keyed by the (seq bucket, batch bucket) grid;
//! the engine overlaps upload/execute/readback and selects executables
//! through its mirrored policy table; and de-batching + reply dispatch
//! run on the completion pool, never on the engine thread.
//!
//! Overload control (DESIGN.md §5.8): admission is bounded (`submit`
//! returns `SubmitError::Busy`, never queues unboundedly), requests
//! carry deadlines that cancel them at de-queue/batch-formation time or
//! via the engine's cancel-before-submit hook — never after device work
//! starts — and an optional `PrecisionGovernor` walks each policy's
//! degradation chain toward cheaper modes under sustained queue pressure.

use std::time::{Duration, Instant};

use crate::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use crate::sync::mpsc::{channel, Receiver, SyncSender, TrySendError};
use crate::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::exec::ThreadPool;
use crate::model::manifest::{Manifest, ModeId, PolicyId, TaskId};
use crate::model::Container;
use crate::runtime::engine::{
    CancelCheck, CancelledBeforeSubmit, Completion, EngineOptions, EnginePool, FaultPlan,
    InferDone, InferJob, ReplicaFailed, RestartPolicy, VersionPayload,
};
use crate::runtime::staging::StagingPool;

use super::batcher::{Batch, Batcher, Drained};
use super::governor::{GovernorConfig, GovernorShared, PrecisionGovernor, Signals};
use super::request::{GroupKey, PolicyRef, Request, RequestSpec, Response, Timing};
use super::stats::Recorder;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_cap: usize,
    pub completion_workers: usize,
    /// Overlap upload/execute/readback in the engine (`false` = the
    /// pre-pipeline serial loop, kept for A/B benchmarking).
    pub pipeline: bool,
    /// Engine replicas behind the load-aware dispatcher (min 1).  Each
    /// replica owns its own PJRT runtime with preloaded checkpoints and
    /// precompiled executables (DESIGN.md §5.7).
    pub replicas: usize,
    /// Staging buffers kept warm per (seq bucket, batch bucket) grid
    /// cell — the warm-buffer bound is
    /// `seq_buckets.len() * buckets.len() * staging_per_cell`.
    pub staging_per_cell: usize,
    /// Deadline applied to requests whose spec carries none (`None` =
    /// such requests never expire).
    pub default_deadline: Option<Duration>,
    /// Enable the load-adaptive precision governor (DESIGN.md §5.8).
    /// Also extends startup preloading to every route's degradation
    /// chain, so a downgraded route always has a resident checkpoint.
    pub governor: Option<GovernorConfig>,
    /// Per-connection socket read timeout of the TCP front end (the
    /// granularity at which connection threads notice shutdown; a slower
    /// client is fine — partial frames survive across timeouts).
    pub net_read_timeout: Duration,
    /// Per-frame byte cap of the TCP front end (one frame is a few KB of
    /// token ids; anything near this cap is a runaway or malicious
    /// stream and drops the connection).
    pub max_frame_bytes: usize,
    /// Heartbeat stall budget for the replica watchdog (DESIGN.md §5.10):
    /// a replica with work in flight whose progress counter stalls this
    /// long is declared dead, swept, and restarted.  `None` disables
    /// stall detection (thread death is always detected).
    pub watchdog: Option<Duration>,
    /// Supervised-restart backoff and circuit-breaker budget.
    pub restart: RestartPolicy,
    /// Structured fault-injection plan (DESIGN.md §5.10): per-replica
    /// scripted panics, stalls, throttles, and slow paths for the chaos
    /// and overload suites.  Empty in production.
    pub fault_plan: FaultPlan,
    /// `Some(latency)` swaps every replica's PJRT device for a fake that
    /// sleeps `latency` per batch and returns zero logits — the
    /// artifact-free path the chaos suite drives the full coordinator
    /// on.  Checkpoint preloading is skipped (routes resolve against the
    /// manifest only).  Never set in production.
    pub fake_engine: Option<Duration>,
    /// Per-replica resident executable-cell budget (DESIGN.md §5.13):
    /// cold (mode, seq bucket, batch bucket) cells LRU-evict past this
    /// count; pinned cells are exempt.  `None` = unbounded.
    pub max_resident_cells: Option<usize>,
    /// Per-replica resident executable byte budget (artifact sizes).
    pub max_resident_bytes: Option<usize>,
    /// Pin the *full* (mode, seq bucket, batch bucket) grid at startup —
    /// the pre-residency eager behavior, kept for A/B benchmarking
    /// (`serve-bench --residency` measures exactly this trade).  The
    /// default pins only each route's (exec mode, seq bucket,
    /// max-batch bucket) cells; everything else loads on demand.
    pub pin_full_grid: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(4),
            queue_cap: 1024,
            completion_workers: 4,
            pipeline: true,
            replicas: 1,
            staging_per_cell: 4,
            default_deadline: None,
            governor: None,
            net_read_timeout: Duration::from_millis(200),
            max_frame_bytes: 1 << 20,
            watchdog: None,
            restart: RestartPolicy::default(),
            fault_plan: FaultPlan::default(),
            fake_engine: None,
            max_resident_cells: None,
            max_resident_bytes: None,
            pin_full_grid: false,
        }
    }
}

/// Typed startup-configuration error: the server must refuse to start on
/// a config the manifest cannot honor, instead of silently serving
/// something else.  The one current case: `max_batch` larger than the
/// manifest's largest batch bucket — `Manifest::bucket_for` would clamp
/// every oversize batch to the largest bucket, so the configured batch
/// size would silently never form.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConfigError {
    /// `ServerConfig::max_batch` (`--max-batch`) exceeds the largest
    /// manifest batch bucket; batches of the configured size could never
    /// execute.
    MaxBatchExceedsBuckets { max_batch: usize, largest_bucket: usize },
    /// `max_batch` of 0 can never form a batch.
    ZeroMaxBatch,
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::MaxBatchExceedsBuckets { max_batch, largest_bucket } => write!(
                f,
                "max_batch {max_batch} exceeds the manifest's largest batch bucket \
                 {largest_bucket}; a batch that size can never execute (lower --max-batch \
                 or regenerate artifacts with a larger bucket)"
            ),
            ConfigError::ZeroMaxBatch => f.write_str("max_batch must be at least 1"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Why `Coordinator::submit` refused a request.  `Busy` is the explicit
/// backpressure signal (the admission queue is at `queue_cap`); the TCP
/// front end maps it to a `busy` response instead of a generic error so
/// clients can distinguish "retry later" from "fix your request".
#[derive(Debug)]
pub enum SubmitError {
    /// Admission queue full — shed, retry later.
    Busy { queue_cap: usize },
    /// Coordinator stopped (shutdown in progress).
    Stopped,
    /// Malformed payload or unknown route — retrying will not help.
    Rejected(anyhow::Error),
}

impl SubmitError {
    pub fn is_busy(&self) -> bool {
        matches!(self, SubmitError::Busy { .. })
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy { queue_cap } => {
                write!(f, "server busy: admission queue full ({queue_cap} deep)")
            }
            SubmitError::Stopped => f.write_str("coordinator stopped"),
            // {:#} flattens the anyhow context chain into one line, the
            // shape callers already match on ("no checkpoint loaded ...")
            SubmitError::Rejected(e) => write!(f, "{e:#}"),
        }
    }
}

impl std::error::Error for SubmitError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SubmitError::Rejected(e) => e.source(),
            _ => None,
        }
    }
}

pub struct Coordinator {
    tx: Option<SyncSender<Request>>,
    batcher_join: Option<crate::sync::thread::JoinHandle<()>>,
    // Drop order matters (declaration order): the engine pool must shut
    // down (each replica draining its queue into completion jobs, joined
    // in replica order) before the worker pool joins, so every admitted
    // request gets a reply or a hangup.
    engine: Option<Arc<EnginePool>>,
    pool: Option<Arc<ThreadPool>>,
    pub recorder: Arc<Recorder>,
    man: Arc<Manifest>,
    /// `[task * num_modes + exec_mode]` -> checkpoint resident in the
    /// engine.  Residency is per executable *mode*: policies that resolve
    /// to the same exec mode share a checkpoint.
    loaded: Vec<bool>,
    /// Admitted-but-unanswered requests, across the *whole* pipeline
    /// (channel + batcher groups + engine queues): submit reserves a
    /// slot, the terminal reply (ok / error / expired) releases it.
    /// Bounding this — not just the channel — is what makes `queue_cap`
    /// an honest backlog bound, and it doubles as the governor's primary
    /// pressure signal.
    depth: Arc<AtomicUsize>,
    /// Present when the governor is enabled: the lock-free
    /// `policy -> effective policy` table admission reads.
    governor: Option<Arc<GovernorShared>>,
    next_id: AtomicU64,
    seq: usize,
    num_labels: usize,
    /// Startup inputs kept for hot reload: `reload` re-reads the
    /// manifest at `artifacts` and rebuilds the same routes against it.
    artifacts: std::path::PathBuf,
    routes: Vec<(String, String)>,
    /// Admission-visible manifest version (DESIGN.md §5.13).  Stored
    /// only after `push_version` broadcast the payload, so a request
    /// stamped with version N is always behind the `Reload(N)` message
    /// in every replica queue.  (`AtomicU64` because the heromck facade
    /// models no `AtomicU32`; the value is a `u32`.)
    current_version: AtomicU64,
    pub config: ServerConfig,
}

/// Expand routes (plus governor degradation chains), read each
/// (task, exec mode) checkpoint, and derive the pin set — everything
/// version-specific the engine needs from one manifest.  `start` and
/// `reload` share this, so a reloaded version installs exactly what a
/// fresh start against the same manifest would.
///
/// The pin set is the cells the configured routes actually serve:
/// each *requested* route's exec mode, across every seq bucket, at the
/// `max_batch` batch bucket.  Governor chain rungs are deliberately not
/// pinned — their checkpoints are resident, but their executables load
/// on demand (or warm on a governed steer), which is what broke the old
/// `(mode x seq x batch) x replicas` preload cross-product.
/// `pin_full_grid` restores the old eager behavior for A/B benches.
fn build_version_payload(
    man: &Arc<Manifest>,
    routes: &[(String, String)],
    config: &ServerConfig,
    version: u32,
) -> Result<(Arc<VersionPayload>, Vec<bool>)> {
    // expand routes with governor degradation targets (uniform
    // policies of cheaper modes), then dedupe by (task, exec mode)
    let mut expanded: Vec<(String, String)> = Vec::new();
    let mut pin_modes = std::collections::BTreeSet::new();
    for (task, policy) in routes {
        expanded.push((task.clone(), policy.clone()));
        pin_modes.insert(man.policy(policy)?.exec_mode.0);
        if config.governor.is_some() {
            let pid = man.policy_id(policy)?;
            for step in man.downgrade_chain(pid) {
                expanded.push((task.clone(), man.policy_name(step).to_string()));
            }
        }
    }

    // load quantized/fp checkpoints from disk, one per (task, exec
    // mode) — routes naming policies with the same exec mode dedupe.
    // Under a fake engine there is nothing to read: routes still
    // resolve and mark their slots resident, but no Container leaves
    // disk and the fake device accepts any preload set.
    let mut preload = Vec::new();
    let mut modes_used = std::collections::BTreeSet::new();
    let mut loaded = vec![false; man.num_tasks() * man.num_modes()];
    for (task, policy) in &expanded {
        let t = man.task(task)?;
        let exec = man.policy(policy)?.exec_mode;
        let mode = man.mode_name(exec).to_string();
        let slot = route_slot(man.num_modes(), man.task_id(task)?, exec);
        if loaded[slot] {
            continue;
        }
        loaded[slot] = true;
        modes_used.insert(exec.0);
        if config.fake_engine.is_some() {
            continue;
        }
        let rel = t.checkpoint_rel(&mode);
        let path = man.path(&rel);
        let ckpt = Container::read_file(&path)
            .with_context(|| format!("loading checkpoint {path:?} (run `repro quantize` first?)"))?
            .reordered(&man.mode(&mode)?.params)?;
        preload.push((task.clone(), mode.clone(), ckpt));
    }

    let pins: Vec<(u16, usize, usize)> = if config.pin_full_grid {
        modes_used
            .iter()
            .flat_map(|m| {
                man.seq_buckets.iter().flat_map(move |s| {
                    man.buckets.iter().map(move |b| (*m, *s, *b))
                })
            })
            .collect()
    } else {
        let bucket = man.bucket_for(config.max_batch);
        pin_modes
            .iter()
            .flat_map(|m| man.seq_buckets.iter().map(move |s| (*m, *s, bucket)))
            .collect()
    };

    let payload = Arc::new(VersionPayload {
        version,
        manifest: Arc::clone(man),
        preload: Arc::new(preload),
        pins: Arc::new(pins),
    });
    Ok((payload, loaded))
}

impl Coordinator {
    /// Load checkpoints for the given (task, policy) routes — mode names
    /// work as uniform policies — spawn the engine and batcher, and pin
    /// each route's (exec mode, seq bucket, max-batch bucket) cells;
    /// other grid cells compile on first demand under the residency
    /// budget (DESIGN.md §5.13).  With the governor enabled, each
    /// route's degradation chain's *checkpoints* are loaded too — a
    /// downgrade must never route to a cold checkpoint — but chain
    /// executables load on demand.
    pub fn start(
        artifacts: std::path::PathBuf,
        routes: &[(String, String)],
        config: ServerConfig,
    ) -> Result<Coordinator> {
        let manifest = Manifest::load(&artifacts)?;
        let seq = manifest.seq;
        let num_labels = manifest.model.num_labels;
        let buckets = manifest.buckets.clone();
        let seq_buckets = manifest.seq_buckets.clone();

        // typed config validation before any thread spawns: an oversize
        // max_batch would otherwise be silently clamped by bucket_for at
        // every dispatch — serving a different batch size than configured
        if config.max_batch == 0 {
            return Err(anyhow::Error::new(ConfigError::ZeroMaxBatch));
        }
        let largest_bucket = *buckets.last().context("manifest declares no buckets")?;
        if config.max_batch > largest_bucket {
            return Err(anyhow::Error::new(ConfigError::MaxBatchExceedsBuckets {
                max_batch: config.max_batch,
                largest_bucket,
            }));
        }

        let man = Arc::new(manifest);
        // version 0's payload: route checkpoints + the startup pin set
        // (only the pin set compiles before ready — DESIGN.md §5.13)
        let (payload, loaded) = build_version_payload(&man, routes, &config, 0)?;

        let pool = Arc::new(ThreadPool::new(config.completion_workers, "zqh-complete"));
        let staging =
            Arc::new(StagingPool::new(&seq_buckets, &buckets, config.staging_per_cell));
        let replicas = config.replicas.max(1);
        // the recorder exists before the pool so its event hook rides
        // along into spawn: supervision telemetry AND the startup pin
        // loads land in the ledger (DESIGN.md §5.10/§5.13 — the
        // residency smoke asserts startup loads == the pin set)
        let recorder = Arc::new(Recorder::new(man.policy_order.clone(), replicas));
        let hook = {
            let rec = Arc::clone(&recorder);
            Arc::new(move |ev| rec.record_pool_event(ev)) as crate::runtime::engine::PoolEventHook
        };
        let engine = Arc::new(EnginePool::spawn(
            payload,
            Arc::clone(&pool),
            Arc::clone(&staging),
            EngineOptions {
                overlap: config.pipeline,
                replicas,
                watchdog: config.watchdog,
                restart: config.restart.clone(),
                fault_plan: config.fault_plan.clone(),
                fake: config.fake_engine,
                max_resident_cells: config.max_resident_cells,
                max_resident_bytes: config.max_resident_bytes,
            },
            Some(hook),
        )?);
        let depth = Arc::new(AtomicUsize::new(0));

        // governor: pure machine on the batcher thread, shared effective
        // table for admission
        let (machine, shared) = match &config.governor {
            Some(cfg) => {
                let chains: Vec<Vec<PolicyId>> = (0..man.num_policies())
                    .map(|i| man.downgrade_chain(PolicyId(i as u16)))
                    .collect();
                let machine = PrecisionGovernor::new(chains, cfg.clone());
                let shared = Arc::new(GovernorShared::new(man.num_policies()));
                (Some(machine), Some(shared))
            }
            None => (None, None),
        };

        let (tx, rx) = crate::sync::mpsc::sync_channel::<Request>(config.queue_cap);
        let batcher_cfg = config.clone();
        let b_recorder = Arc::clone(&recorder);
        let b_engine = Arc::clone(&engine);
        let b_man = Arc::clone(&man);
        let b_depth = Arc::clone(&depth);
        let b_shared = shared.clone();
        let batcher_join = crate::sync::thread::Builder::new()
            .name("zqh-batcher".into())
            .spawn(move || {
                batcher_main(
                    rx, batcher_cfg, b_man, b_engine, b_recorder, staging, b_depth, machine,
                    b_shared,
                )
            })
            .context("spawn batcher")?;

        Ok(Coordinator {
            tx: Some(tx),
            batcher_join: Some(batcher_join),
            engine: Some(engine),
            pool: Some(pool),
            recorder,
            man,
            loaded,
            depth,
            governor: shared,
            next_id: AtomicU64::new(0),
            seq,
            num_labels,
            artifacts,
            routes: routes.to_vec(),
            current_version: AtomicU64::new(0),
            config,
        })
    }

    /// Hot-reload the manifest at the startup `artifacts` path
    /// (DESIGN.md §5.13): the new manifest must be grid-compatible
    /// (identical mode/policy/task orders and bucket grids — a reload is
    /// a *weights/artifact* refresh; grid changes need a restart).  The
    /// new version's checkpoints and pin set are broadcast to every
    /// replica first; only then does the admission version advance, so
    /// new requests route to the new version while in-flight requests
    /// drain on the old one, whose cells unpin and age out via LRU.
    /// Returns the new version number.
    pub fn reload(&self) -> Result<u32> {
        let next = Manifest::load(&self.artifacts)?;
        self.man
            .grid_compatible(&next)
            .context("manifest changed incompatibly; hot reload refused")?;
        let next = Arc::new(next);
        let version = self.current_version.load(Ordering::SeqCst) as u32 + 1;
        let (payload, _loaded) = build_version_payload(&next, &self.routes, &self.config, version)?;
        // order matters: ledger slots exist before any event can carry
        // the new version; replicas hold the payload before any request
        // can be stamped with it
        self.recorder.register_version(version);
        self.engine().push_version(payload);
        self.current_version.store(version as u64, Ordering::SeqCst);
        Ok(version)
    }

    /// The admission-visible manifest version (requests admitted now are
    /// stamped with it).
    pub fn current_version(&self) -> u32 {
        self.current_version.load(Ordering::SeqCst) as u32
    }

    /// Submit a typed request.  Policy references are interned here —
    /// nothing downstream sees a string — the deadline is stamped, the
    /// request's *real* length is recorded (no padding to the model max:
    /// the smallest manifest seq bucket that fits becomes the request's
    /// batching class, DESIGN.md §5.9), and under an active governor
    /// downgrade the request rides the cheaper effective route (ledgered
    /// as `governed` on the requested policy).  `Err(SubmitError::Busy)`
    /// is explicit backpressure: the admission queue never grows past
    /// `queue_cap`.
    pub fn submit(
        &self,
        spec: RequestSpec,
    ) -> std::result::Result<Receiver<Response>, SubmitError> {
        let RequestSpec { task, policy, ids, type_ids, deadline } = spec;
        let reject = |e: anyhow::Error| SubmitError::Rejected(e);
        if ids.is_empty() || ids.len() > self.seq {
            return Err(reject(anyhow!(
                "request needs 1..={} token ids (got {})",
                self.seq,
                ids.len()
            )));
        }
        let mut type_ids = type_ids.unwrap_or_default();
        if type_ids.len() > self.seq {
            return Err(reject(anyhow!(
                "type_ids longer than seq {} (got {})",
                self.seq,
                type_ids.len()
            )));
        }
        // pre-grid clients padded type_ids client-side; a tail beyond the
        // real token count rides masked PAD positions, so truncating (not
        // rejecting) keeps every previously-valid frame valid
        type_ids.resize(ids.len(), 0);
        // the request's sequence-length class: padding to this bucket
        // happens at staging, per batch — never here to the model max
        let seq_bucket = self.man.seq_bucket_for(ids.len());
        let key = self.resolve(&task, policy.as_ref()).map_err(reject)?;
        let requested = key.policy;
        // governed routing: the effective policy may sit further down the
        // degradation chain right now.  Chain targets of the *configured*
        // routes were preloaded at start; a request naming some other
        // admissible policy (or another task) could still be steered at a
        // cold (task, mode) slot, so check residency and fall back to the
        // requested route rather than dispatch to a checkpoint the engine
        // never loaded.
        let effective = match &self.governor {
            Some(g) => {
                let eff = g.effective(requested);
                let exec = self.man.policy_by_id(eff).exec_mode;
                if eff == requested {
                    eff
                } else if !self.loaded[route_slot(self.man.num_modes(), key.task, exec)] {
                    requested
                } else if !self.engine().any_resident(key.version, exec, seq_bucket) {
                    // the governed rung's executable cell is cold on
                    // every replica: a downshifted batch would stall the
                    // pressure path behind a compile — the opposite of
                    // what the governor is for.  Serve the requested
                    // route now and warm the rung in the background; the
                    // steer takes effect once the cell is resident
                    // (DESIGN.md §5.13).
                    self.engine().warm(
                        key.version,
                        exec,
                        seq_bucket,
                        self.man.bucket_for(self.config.max_batch),
                    );
                    requested
                } else {
                    eff
                }
            }
            None => requested,
        };
        // reserve a backlog slot before touching the channel: `depth`
        // counts admitted-but-unanswered requests, so the bound covers
        // everything downstream (batcher groups, engine queues), not just
        // the channel — the channel itself (also `queue_cap` deep) can
        // then never reject a reserved request
        let busy = || SubmitError::Busy { queue_cap: self.config.queue_cap };
        if self.depth.fetch_add(1, Ordering::SeqCst) >= self.config.queue_cap {
            self.depth.fetch_sub(1, Ordering::SeqCst);
            self.recorder.record_shed_at(key.version, requested);
            return Err(busy());
        }
        let now = Instant::now();
        let (reply, rx) = channel();
        let req = Request {
            // relaxed-ok: pure id allocation — uniqueness is all that
            // matters and fetch_add gives it at any ordering
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            key: GroupKey { task: key.task, policy: effective, version: key.version },
            requested,
            seq_bucket,
            ids,
            type_ids,
            enqueued: now,
            deadline: deadline.or(self.config.default_deadline).map(|d| now + d),
            reply,
        };
        // panic-ok: tx is Some from construction until drop; submit on a
        // dropped coordinator is a caller bug, not a runtime state
        match self.tx.as_ref().expect("live").try_send(req) {
            Ok(()) => {
                if effective != requested {
                    self.recorder.record_governed_at(key.version, requested);
                }
                Ok(rx)
            }
            Err(TrySendError::Full(_)) => {
                // unreachable by construction (reservations cap channel
                // occupancy), kept as defense in depth
                self.depth.fetch_sub(1, Ordering::SeqCst);
                self.recorder.record_shed_at(key.version, requested);
                Err(busy())
            }
            Err(TrySendError::Disconnected(_)) => {
                self.depth.fetch_sub(1, Ordering::SeqCst);
                Err(SubmitError::Stopped)
            }
        }
    }

    /// Intern (task, policy) and check the policy's executable mode has a
    /// resident checkpoint.
    fn resolve(&self, task: &str, policy: Option<&PolicyRef>) -> Result<GroupKey> {
        let label = match policy {
            // a manifest with no modes has no default route to fall back
            // to — reject, rather than fabricating an empty-string mode
            // that fails later with a misleading "unknown mode" error
            None => match self.man.mode_order.first() {
                Some(m) => m.clone(),
                None => {
                    return Err(anyhow!(
                        "manifest declares no modes; a request without an explicit \
                         policy has no default route"
                    ))
                }
            },
            Some(PolicyRef::Named(n)) => n.clone(),
            Some(PolicyRef::Inline(_)) => "<inline>".to_string(),
        };
        let no_ckpt = |detail: &str| {
            anyhow!(
                "no checkpoint loaded for ({task},{label}){detail}; not in this server's routes"
            )
        };
        let task_id = self.man.task_id(task).map_err(|_| no_ckpt(""))?;
        let pid = match policy {
            None => PolicyId(0), // uniform policy of the manifest's first mode
            Some(PolicyRef::Named(n)) => self.man.policy_id(n).map_err(|_| no_ckpt(""))?,
            Some(PolicyRef::Inline(draft)) => self.man.intern_inline_policy(draft)?,
        };
        let exec = self.man.policy_by_id(pid).exec_mode;
        if !self.loaded[route_slot(self.man.num_modes(), task_id, exec)] {
            let detail = format!(" — policy executes mode {:?}", self.man.mode_name(exec));
            return Err(no_ckpt(&detail));
        }
        let version = self.current_version.load(Ordering::SeqCst) as u32;
        Ok(GroupKey { task: task_id, policy: pid, version })
    }

    /// The coordinator-side manifest (policy/route tables; parity tests
    /// compare these against the engine's mirrored tables).
    pub fn manifest(&self) -> &Manifest {
        &self.man
    }

    /// The engine pool handle (mirrored route/policy tables, dispatch
    /// state introspection).
    pub fn engine(&self) -> &EnginePool {
        // panic-ok: engine is Some from construction until drop
        self.engine.as_ref().expect("engine live")
    }

    /// The governor's current effective route for `policy` (identity
    /// when the governor is off) — introspection for tests/benches.
    pub fn effective_policy(&self, policy: PolicyId) -> PolicyId {
        match &self.governor {
            Some(g) => g.effective(policy),
            None => policy,
        }
    }

    /// Admitted-but-unanswered requests across the whole pipeline
    /// (introspection; the governor's pressure signal).
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::SeqCst)
    }

    pub fn num_labels(&self) -> usize {
        self.num_labels
    }

    pub fn seq(&self) -> usize {
        self.seq
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the queue; batcher drains and exits
        if let Some(j) = self.batcher_join.take() {
            let _ = j.join();
        }
        // engine pool before worker pool: EnginePool::drop stops every
        // replica (queues drain concurrently into completion jobs) and
        // joins them in replica order; ThreadPool::drop then runs all
        // pending completions.
        drop(self.engine.take());
        drop(self.pool.take());
    }
}

/// Flat slot of a (task, exec mode) route in the `loaded` bitmap — the
/// one definition of the 2D->1D layout.
fn route_slot(num_modes: usize, task: TaskId, mode: ModeId) -> usize {
    task.index() * num_modes + mode.index()
}

#[allow(clippy::too_many_arguments)]
fn batcher_main(
    rx: Receiver<Request>,
    config: ServerConfig,
    man: Arc<Manifest>,
    engine: Arc<EnginePool>,
    recorder: Arc<Recorder>,
    staging: Arc<StagingPool>,
    depth: Arc<AtomicUsize>,
    mut governor: Option<PrecisionGovernor>,
    shared: Option<Arc<GovernorShared>>,
) {
    let mut batcher = Batcher::new(config.max_batch, config.max_wait);
    let mut batch_seq: u64 = 0;
    // queue delay of the most recently dispatched batch — the governor's
    // instantaneous latency signal
    let mut last_queue_us: u64 = 0;
    let gov_tick = governor.as_ref().map(|g| g.config().tick);
    let mut last_gov = Instant::now();
    let idle = match gov_tick {
        // with a governor, idle wake-ups follow its cadence so restore
        // streaks accumulate even on a quiet server
        Some(t) => t.max(Duration::from_millis(1)),
        None => Duration::from_millis(50),
    };
    let mut finish = |out: Drained, batch_seq: &mut u64, last_queue_us: &mut u64| {
        let now = Instant::now();
        for r in out.expired {
            // batcher-side expiry is terminal here, so this is where its
            // backlog slot releases (batch completions release their
            // own); release-before-reply, like the completion path, so
            // an observer who has every reply also sees a drained backlog
            depth.fetch_sub(1, Ordering::SeqCst);
            send_expired(&r, &recorder, now);
        }
        for batch in out.batches {
            if let Some(front) = batch.requests.first() {
                *last_queue_us = now.duration_since(front.enqueued).as_micros() as u64;
            }
            dispatch(batch, batch_seq, &config, &man, &engine, &recorder, &staging, &depth);
        }
    };
    loop {
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()).min(idle))
            .unwrap_or(idle);
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                let out = batcher.push(req, Instant::now());
                finish(out, &mut batch_seq, &mut last_queue_us);
            }
            Err(crate::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(crate::sync::mpsc::RecvTimeoutError::Disconnected) => {
                let out = batcher.drain_all(Instant::now());
                finish(out, &mut batch_seq, &mut last_queue_us);
                break;
            }
        }
        let out = batcher.tick(Instant::now());
        finish(out, &mut batch_seq, &mut last_queue_us);

        // governor cadence: observe the whole-pipeline backlog, publish
        // any transitions to the table admission reads
        if let (Some(g), Some(table)) = (governor.as_mut(), shared.as_deref()) {
            let now = Instant::now();
            // panic-ok: gov_tick is Some whenever governor is Some (both
            // derive from the same config branch)
            if now.duration_since(last_gov) >= gov_tick.expect("governor has a tick") {
                last_gov = now;
                let signals = Signals {
                    depth: depth.load(Ordering::SeqCst),
                    queue_us: last_queue_us,
                };
                // consume the latency sample: each dispatched batch's
                // queue delay feeds exactly one observation, so a single
                // slow batch cannot keep tripping `high_queue_us` for its
                // whole in-flight duration (or forever on an idle server)
                // — sustained pressure requires freshly slow batches
                last_queue_us = 0;
                for ev in g.observe(signals) {
                    table.publish(ev.policy, ev.to);
                }
            }
        }
    }
}

/// Assemble a batch into a pooled staging buffer and hand it to the
/// engine pool with a completion callback (de-batching + reply dispatch,
/// run on the worker pool after readback).  The pool routes the batch to
/// the group's pinned replica, or the least-loaded one.  Batches whose
/// every member carries a deadline also carry a cancel-before-submit
/// check: if the whole batch expires while queued inside the engine, it
/// is abandoned before any device work (DESIGN.md §5.8).
#[allow(clippy::too_many_arguments)]
fn dispatch(
    batch: Batch,
    batch_seq: &mut u64,
    config: &ServerConfig,
    man: &Arc<Manifest>,
    engine: &Arc<EnginePool>,
    recorder: &Arc<Recorder>,
    staging: &Arc<StagingPool>,
    depth: &Arc<AtomicUsize>,
) {
    let real = batch.requests.len();
    let bucket = man.bucket_for(real);
    // the batch's seq bucket came from the batcher's class partition:
    // the smallest manifest bucket that fits its longest member
    let seq_bucket = batch.seq_bucket;
    let dispatched = Instant::now();
    let seq_no = *batch_seq;
    *batch_seq += 1;

    let mut host = staging.take(seq_bucket, bucket);
    for r in &batch.requests {
        host.push_row(&r.ids, &r.type_ids);
    }
    host.finish();
    let real_tokens = host.real_tokens;
    let padded_tokens = host.padded_tokens();

    // the batch is cancellable only while every member has a deadline:
    // once the last of them passes, no one is waiting for the result
    let cancel: Option<CancelCheck> = batch
        .requests
        .iter()
        .map(|r| r.deadline)
        .collect::<Option<Vec<Instant>>>()
        .and_then(|ds| ds.into_iter().max())
        .map(|latest| Box::new(move || Instant::now() >= latest) as CancelCheck);

    let policy = batch.key.policy;
    let version = batch.key.version;
    let requests = batch.requests;
    let recorder = Arc::clone(recorder);
    let depth = Arc::clone(depth);
    let fault = config.fault_plan.completion_panic();
    let done = Completion::new(move |result: Result<InferDone>| {
        // release the whole batch's backlog reservations first, before
        // any work that can panic (the worker pool isolates panics, and
        // a poisoned batch must not shrink admission capacity forever —
        // the same decrement-before-user-code rule DispatchState uses)
        depth.fetch_sub(requests.len(), Ordering::SeqCst);
        if fault == Some(seq_no) {
            panic!("fault injection: completion panic for batch {seq_no}");
        }
        match result {
            Ok(done) => {
                let logits = match done.logits.as_f32() {
                    Ok(v) => v.to_vec(),
                    Err(e) => {
                        let msg = format!("bad logits: {e}");
                        for r in requests {
                            send_error(&r, policy, &recorder, &msg);
                        }
                        return;
                    }
                };
                let nl = logits.len() / bucket;
                recorder.record_batch_at(
                    version,
                    policy,
                    real,
                    real_tokens,
                    padded_tokens,
                    done.exec_us,
                    done.replica,
                );
                for (row, r) in requests.into_iter().enumerate() {
                    let now = Instant::now();
                    let timing = Timing {
                        queue_us: dispatched.duration_since(r.enqueued).as_micros() as u64,
                        exec_us: done.exec_us,
                        upload_us: done.upload_us,
                        engine_us: done.engine_us,
                        total_us: now.duration_since(r.enqueued).as_micros() as u64,
                        batch_real: real,
                        bucket,
                        seq_bucket,
                        real_tokens,
                        padded_tokens,
                        batch_seq: seq_no,
                        replica: done.replica,
                        engine_seq: done.exec_seq,
                        load_wait_us: done.load_wait_us,
                    };
                    recorder.record_request_at(
                        version,
                        r.requested,
                        timing.total_us,
                        timing.queue_us,
                        false,
                    );
                    let _ = r.reply.send(Response {
                        id: r.id,
                        policy,
                        // panic-ok: the engine returns bucket*nl logits
                        // and row < rows <= bucket by batch formation
                        logits: logits[row * nl..(row + 1) * nl].to_vec(),
                        timing,
                        error: None,
                        expired: false,
                        failed: false,
                        busy: false,
                    });
                }
            }
            Err(e) if e.downcast_ref::<CancelledBeforeSubmit>().is_some() => {
                // the engine abandoned the whole batch before any device
                // work: every member expired while queued — the second
                // (and last) cancellation point after batch formation
                let now = Instant::now();
                for r in requests {
                    send_expired(&r, &recorder, now);
                }
            }
            Err(e) if e.downcast_ref::<ReplicaFailed>().is_some() => {
                // the replica holding this batch died (panic, stall, or
                // shutdown sweep) — a typed outcome class distinct from
                // request errors: the request was fine, the engine was
                // not, and a retry on the recovered pool should succeed
                for r in requests {
                    send_failed(&r, policy, &recorder);
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for r in requests {
                    send_error(&r, policy, &recorder, &msg);
                }
            }
        }
    });

    let job = InferJob { task: batch.key.task, policy, version, staging: host, cancel, done };
    if let Err(job) = engine.submit(job) {
        let job = *job;
        staging.put(job.staging);
        job.done.run(Err(anyhow!("engine unavailable")));
    }
}

/// NB: neither reply helper touches the backlog counter — batch
/// completions release all their reservations up front (panic safety),
/// and the batcher-side expiry path decrements explicitly in `finish`.
fn send_error(r: &Request, policy: PolicyId, recorder: &Recorder, msg: &str) {
    recorder.record_request_at(r.key.version, r.requested, 0, 0, true);
    let _ = r.reply.send(Response {
        id: r.id,
        policy,
        logits: vec![],
        timing: Timing::default(),
        error: Some(msg.to_string()),
        expired: false,
        failed: false,
        busy: false,
    });
}

/// Reply to a request whose batch was swept off a dead replica
/// (DESIGN.md §5.10): ledgered as `failed` — a class of its own so the
/// overload ledger still reconciles exactly under chaos
/// (admitted = completed + shed + expired + failed).
fn send_failed(r: &Request, policy: PolicyId, recorder: &Recorder) {
    recorder.record_failed_at(r.key.version, r.requested);
    let _ = r.reply.send(Response {
        id: r.id,
        policy,
        logits: vec![],
        timing: Timing::default(),
        error: Some("engine replica failed before the batch completed".to_string()),
        expired: false,
        failed: true,
        busy: false,
    });
}

/// Reply to a deadline-expired request: a distinct outcome class, with
/// queue time but — by construction — no engine timings (cancellation
/// never happens after device work starts).
fn send_expired(r: &Request, recorder: &Recorder, now: Instant) {
    let queue_us = now.duration_since(r.enqueued).as_micros() as u64;
    recorder.record_expired_at(r.key.version, r.requested, queue_us);
    let _ = r.reply.send(Response {
        id: r.id,
        policy: r.key.policy,
        logits: vec![],
        timing: Timing { queue_us, ..Timing::default() },
        error: Some(format!("deadline exceeded after {queue_us}us in queue")),
        expired: true,
        failed: false,
        busy: false,
    });
}
