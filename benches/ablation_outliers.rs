//! Outlier-sensitivity ablation: reproduces the *mechanism* behind the
//! paper's CoLA-M3 collapse (61.05 -> 41.65 Mcc).
//!
//! Our build-time-trained tiny models lack the per-channel activation
//! outliers real pretrained BERTs develop, so plain SynGLUE quantization is
//! benign (Table 2).  This bench injects outliers with a
//! function-preserving transform (quant::outliers — `A = QK^T` and
//! `P V W_o` are exactly invariant), then re-runs the PTQ pipeline: FP
//! stays put, the INT8 attention modes degrade with alpha — the paper's
//! sensitivity profile, demonstrated causally.
//!
//! Env: ZQH_TASK (default cola).

use zqhero::bench::Table;
use zqhero::evalharness as eh;
use zqhero::model::manifest::Manifest;
use zqhero::model::Container;
use zqhero::quant::outliers::{inject_outliers, OutlierSpec};
use zqhero::runtime::Runtime;

fn main() {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("ablation_outliers: run `make artifacts` first");
        return;
    }
    let tname = std::env::var("ZQH_TASK").unwrap_or_else(|_| "cola".into());
    let rt = Runtime::new(Manifest::load(&dir).unwrap()).unwrap();
    let task = rt.manifest.task(&tname).unwrap().clone();
    let fp_path = rt.manifest.path(&task.checkpoint);
    let fp_orig = Container::read_file(&fp_path).unwrap();
    let cfg = rt.manifest.model.clone();

    println!("\nOutlier-sensitivity ablation on {tname} (paper: CoLA collapses at M3)");
    println!("transform: scale {}/head Q,V channels by alpha; K,O inversely (FP-invariant)\n",
             OutlierSpec::default().channels_per_head);

    let mut t = Table::new(&["alpha", "FP", "M2 (attn INT8)", "M3"]);
    let backup = dir.join(format!("checkpoints/{tname}/fp32.orig.bin"));
    fp_orig.write_file(&backup).unwrap();

    for alpha in [1.0f32, 8.0, 32.0, 128.0] {
        let spec = OutlierSpec { alpha, ..Default::default() };
        let injected = inject_outliers(&fp_orig, &cfg, &spec).unwrap();
        // swap the on-disk fp checkpoint so the whole pipeline (calibration
        // included — the stats must see the outliers) runs on it
        injected.write_file(&fp_path).unwrap();
        std::fs::remove_file(dir.join(format!("checkpoints/{tname}/calib.json"))).ok();

        let mut row = vec![format!("{alpha}")];
        for mode in ["fp", "m2", "m3"] {
            let mut rt2 = Runtime::new(Manifest::load(&dir).unwrap()).unwrap();
            let hist = if mode == "fp" {
                None
            } else {
                Some(eh::ensure_calibration(&mut rt2, &task, 100, false).unwrap())
            };
            if let Some(h) = &hist {
                let ckpt = eh::quantize_task(&mut rt2, &task, mode, h, 100.0,
                                             Some(&format!("out{alpha}"))).unwrap();
                rt2.upload_checkpoint(&task.name, mode, &ckpt).unwrap();
            } else {
                eh::ensure_checkpoint(&mut rt2, &task, "fp", 100, 100.0).unwrap();
            }
            let vals = eh::eval_split(&mut rt2, &task, mode, "dev").unwrap();
            let first = *vals.values().next().unwrap();
            row.push(format!("{:.2}", first * 100.0));
        }
        t.row(row);
    }

    // restore the original checkpoint + calibration
    fp_orig.write_file(&fp_path).unwrap();
    std::fs::remove_file(dir.join(format!("checkpoints/{tname}/calib.json"))).ok();
    std::fs::remove_file(&backup).ok();

    t.print();
    println!("\nFP is invariant under the transform; INT8 attention (SQ per-tensor");
    println!("scales) degrades as outlier channels eat the quantization range —");
    println!("the paper's sensitive-task mechanism, reproduced causally.");
}
