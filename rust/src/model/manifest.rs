//! Typed view over `artifacts/manifest.json` — the L2→L3 contract
//! (model config, per-mode parameter signatures, artifact paths, tasks).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::json::{self, Value};

use super::tensor::DType;

/// Dense route id for a task, an index into `Manifest::task_order`.
///
/// The manifest is the single source of truth for the id space: every
/// component that loads the same `manifest.json` (coordinator, engine
/// thread, CLI) derives identical ids, so they can be passed across
/// threads without a handshake.  Strings are resolved to ids exactly once
/// at admission (DESIGN.md §5.2); everything downstream is `Copy`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub u16);

/// Dense route id for a precision mode, an index into `Manifest::mode_order`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModeId(pub u16);

/// Dense id for a precision policy, an index into `Manifest::policy_order`.
///
/// The id space is fixed at manifest load: the uniform per-mode policies
/// come first (so `PolicyId(i)` and `ModeId(i)` name the same route for
/// `i < num_modes`), followed by the manifest's `policies` section in
/// declaration order.  Inline wire specs intern into this space at
/// admission (DESIGN.md §6.3), so the hot path stays `Copy`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PolicyId(pub u16);

impl TaskId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl ModeId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl PolicyId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// The one definition of name -> dense-id interning, shared by
/// `Manifest::{task_id,mode_id}` and the engine's mirrored route tables.
pub fn intern_position(order: &[String], name: &str) -> Option<u16> {
    order.iter().position(|n| n == name).map(|i| i as u16)
}

#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub vocab_size: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub max_seq: usize,
    pub type_vocab: usize,
    pub num_labels: usize,
    pub ln_eps: f64,
}

impl ModelCfg {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Switches {
    pub embedding: bool,
    pub qkv: bool,
    pub attn: bool,
    pub attn_output: bool,
    pub fc1: bool,
    pub fc2: bool,
}

impl Switches {
    pub const ALL_OFF: Switches = Switches {
        embedding: false,
        qkv: false,
        attn: false,
        attn_output: false,
        fc1: false,
        fc2: false,
    };

    pub fn tag(&self) -> String {
        [self.embedding, self.qkv, self.attn, self.attn_output, self.fc1, self.fc2]
            .iter()
            .map(|b| if *b { '1' } else { '0' })
            .collect()
    }

    /// Table-1 row as the paper prints it.
    pub fn row(&self) -> [bool; 6] {
        [self.embedding, self.qkv, self.attn, self.attn_output, self.fc1, self.fc2]
    }

    pub fn get(&self, g: ModuleGroup) -> bool {
        match g {
            ModuleGroup::Embedding => self.embedding,
            ModuleGroup::Qkv => self.qkv,
            ModuleGroup::Attn => self.attn,
            ModuleGroup::AttnOutput => self.attn_output,
            ModuleGroup::Fc1 => self.fc1,
            ModuleGroup::Fc2 => self.fc2,
        }
    }

    pub fn set(&mut self, g: ModuleGroup, int8: bool) {
        match g {
            ModuleGroup::Embedding => self.embedding = int8,
            ModuleGroup::Qkv => self.qkv = int8,
            ModuleGroup::Attn => self.attn = int8,
            ModuleGroup::AttnOutput => self.attn_output = int8,
            ModuleGroup::Fc1 => self.fc1 = int8,
            ModuleGroup::Fc2 => self.fc2 = int8,
        }
    }

    /// True iff every INT8 module of `self` is also INT8 in `other` — the
    /// escalation rule: a fallback mode may only *raise* precision
    /// relative to what a policy asked for, never quantize more.
    pub fn subset_of(&self, other: &Switches) -> bool {
        let a = self.row();
        let b = other.row();
        a.iter().zip(b.iter()).all(|(x, y)| !*x || *y)
    }

    /// Number of INT8 module groups — the cost order the overload
    /// governor walks (more INT8 = cheaper to execute, DESIGN.md §5.8).
    pub fn int8_count(&self) -> usize {
        self.row().iter().filter(|b| **b).count()
    }
}

/// The paper's per-module quantization groups (Table 1 columns) — the
/// granularity at which a `PrecisionPolicy` can override the base mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModuleGroup {
    Embedding,
    Qkv,
    Attn,
    AttnOutput,
    Fc1,
    Fc2,
}

impl ModuleGroup {
    pub const ALL: [ModuleGroup; 6] = [
        ModuleGroup::Embedding,
        ModuleGroup::Qkv,
        ModuleGroup::Attn,
        ModuleGroup::AttnOutput,
        ModuleGroup::Fc1,
        ModuleGroup::Fc2,
    ];

    pub fn name(self) -> &'static str {
        match self {
            ModuleGroup::Embedding => "embedding",
            ModuleGroup::Qkv => "qkv",
            ModuleGroup::Attn => "attn",
            ModuleGroup::AttnOutput => "attn_output",
            ModuleGroup::Fc1 => "fc1",
            ModuleGroup::Fc2 => "fc2",
        }
    }

    pub fn parse(s: &str) -> Result<ModuleGroup> {
        Self::ALL.iter().copied().find(|g| g.name() == s).with_context(|| {
            let names: Vec<&str> = Self::ALL.iter().map(|g| g.name()).collect();
            format!("unknown module group {s:?} (have {names:?})")
        })
    }
}

/// Requested precision for one module group inside a policy override.
/// Anything non-INT8 maps to `Fp`: on this testbed the reference path is
/// FP32, standing in for the paper's FP16/BF16 recovery precision.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModulePrecision {
    Int8,
    Fp,
}

impl ModulePrecision {
    pub fn name(self) -> &'static str {
        match self {
            ModulePrecision::Int8 => "int8",
            ModulePrecision::Fp => "fp",
        }
    }

    pub fn parse(s: &str) -> Result<ModulePrecision> {
        match s {
            "int8" | "i8" => Ok(ModulePrecision::Int8),
            "fp" | "fp16" | "bf16" | "fp32" => Ok(ModulePrecision::Fp),
            _ => bail!("unknown precision {s:?} (have [\"int8\", \"fp\"])"),
        }
    }
}

/// Unresolved precision-policy request, exactly as it appears on the wire
/// (v2 inline frames) or in the manifest `policies` section: names are
/// not yet validated against `mode_order`.  Resolution
/// (`Manifest::resolve_policy`) turns a draft into a `PolicySpec`.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct PolicyDraft {
    /// Whole-model base mode name.
    pub base: String,
    /// Ordered `(module group, precision)` overrides, applied in order.
    pub overrides: Vec<(String, String)>,
    /// Accuracy-fallback escalation chain: mode names tried in order when
    /// no artifact matches the effective switches exactly.
    pub fallback: Vec<String>,
}

impl PolicyDraft {
    pub fn base(mode: &str) -> PolicyDraft {
        PolicyDraft { base: mode.to_string(), ..Default::default() }
    }

    pub fn with_override(mut self, group: &str, precision: &str) -> PolicyDraft {
        self.overrides.push((group.to_string(), precision.to_string()));
        self
    }

    pub fn with_fallback(mut self, mode: &str) -> PolicyDraft {
        self.fallback.push(mode.to_string());
        self
    }

    /// Parse the JSON policy grammar (shared by the manifest section and
    /// inline v2 wire specs):
    /// `{"base": "m3", "overrides": [["attn_output", "fp"], ...],
    ///   "fallback": ["m2", "m1", "fp"]}` — overrides/fallback optional.
    pub fn from_json(v: &Value) -> Result<PolicyDraft> {
        // strict keys: a misspelled "overrides" must not silently collapse
        // the policy to its uniform base mode
        for (k, _) in v.as_object().context("policy spec not an object")? {
            match k.as_str() {
                "base" | "overrides" | "fallback" => {}
                other => bail!(
                    "unknown policy key {other:?} (have [\"base\", \"overrides\", \"fallback\"])"
                ),
            }
        }
        let base = v.req("base")?.as_str().context("policy base not a string")?.to_string();
        let mut overrides = Vec::new();
        if let Some(ov) = v.get("overrides") {
            for item in ov.as_array().context("policy overrides not an array")? {
                let t = item.as_array().context("override not a [group, precision] pair")?;
                if t.len() != 2 {
                    bail!("override must be a [group, precision] pair");
                }
                overrides.push((
                    t[0].as_str().context("override group not a string")?.to_string(),
                    t[1].as_str().context("override precision not a string")?.to_string(),
                ));
            }
        }
        let mut fallback = Vec::new();
        if let Some(fv) = v.get("fallback") {
            for item in fv.as_array().context("policy fallback not an array")? {
                fallback.push(item.as_str().context("fallback mode not a string")?.to_string());
            }
        }
        Ok(PolicyDraft { base, overrides, fallback })
    }

    /// Inverse of `from_json` (the v2 client serializes inline specs).
    pub fn to_json(&self) -> Value {
        let mut pairs = vec![("base", Value::String(self.base.clone()))];
        if !self.overrides.is_empty() {
            pairs.push((
                "overrides",
                Value::Array(
                    self.overrides
                        .iter()
                        .map(|(g, p)| {
                            Value::Array(vec![
                                Value::String(g.clone()),
                                Value::String(p.clone()),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if !self.fallback.is_empty() {
            pairs.push((
                "fallback",
                Value::Array(self.fallback.iter().map(|m| Value::String(m.clone())).collect()),
            ));
        }
        json::obj(pairs)
    }
}

/// A resolved precision policy (paper §3's mixed-precision contribution
/// as a first-class route): base mode + per-module overrides + fallback
/// chain, validated against `mode_order` at manifest load so admission
/// never fails on a manifest policy.
#[derive(Debug, Clone)]
pub struct PolicySpec {
    pub name: String,
    pub base: ModeId,
    pub overrides: Vec<(ModuleGroup, ModulePrecision)>,
    pub fallback: Vec<ModeId>,
    /// Base switches with the overrides applied — what the caller asked for.
    pub effective: Switches,
    /// The mode whose compiled artifact serves this policy: the exact
    /// switch match if one exists, else the first fallback that only
    /// escalates precision.
    pub exec_mode: ModeId,
}

impl PolicySpec {
    /// The implicit whole-model policy every mode desugars to (v1 wire
    /// requests and plain `--mode` flags route through these).
    pub fn uniform(name: &str, mode: ModeId, switches: Switches) -> PolicySpec {
        PolicySpec {
            name: name.to_string(),
            base: mode,
            overrides: Vec::new(),
            fallback: Vec::new(),
            effective: switches,
            exec_mode: mode,
        }
    }

    /// True when this policy is just "run mode X everywhere".
    pub fn is_uniform(&self) -> bool {
        self.overrides.is_empty() && self.base == self.exec_mode
    }
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ModeSpec {
    pub name: String,
    pub switches: Switches,
    pub params: Vec<ParamSpec>,
    /// (seq bucket, batch bucket) -> artifact path relative to the
    /// artifacts root.  Format_version 2 manifests key artifacts by batch
    /// bucket only (`"b16"`); the loader maps those to `(seq, batch)` so a
    /// v2 manifest serves identically through the grid-shaped tables.
    pub artifacts: BTreeMap<(usize, usize), String>,
}

#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub name: String,
    /// 0 = regression (STS-B).
    pub classes: usize,
    pub metrics: Vec<String>,
    pub splits: BTreeMap<String, String>,
    pub checkpoint: String,
}

impl TaskSpec {
    /// Manifest-relative checkpoint path for this task in `mode`: the
    /// trained fp checkpoint for the reference mode, the HERO-quantized
    /// one otherwise.  (Lives here with the task's other path logic —
    /// `splits`/`checkpoint` — not in the coordinator.)
    pub fn checkpoint_rel(&self, mode: &str) -> String {
        if mode == "fp" {
            self.checkpoint.clone()
        } else {
            format!("checkpoints/{}/hero-{}.bin", self.name, mode)
        }
    }
}

#[derive(Debug, Clone)]
pub struct CalibSpec {
    pub artifact: String,
    pub batch: usize,
    pub params: Vec<ParamSpec>,
    /// stat name -> shape, in artifact output order (after logits).
    pub stats: Vec<(String, Vec<usize>)>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub model: ModelCfg,
    /// Maximum (and default) sequence length — the last seq bucket.
    pub seq: usize,
    /// Ascending sequence-length buckets (format_version 3); a manifest
    /// without the `seq_buckets` key (format_version 2) collapses to the
    /// single-bucket axis `[seq]` and serves identically to before the
    /// grid existed.  Invariants enforced at load: non-empty, strictly
    /// ascending, last element == `seq`.
    pub seq_buckets: Vec<usize>,
    /// Ascending batch-size buckets.
    pub buckets: Vec<usize>,
    pub modes: BTreeMap<String, ModeSpec>,
    /// Mode order as listed in the manifest (fp, m1, m2, m3).
    pub mode_order: Vec<String>,
    pub calib: CalibSpec,
    pub tasks: BTreeMap<String, TaskSpec>,
    pub task_order: Vec<String>,
    /// Precision policies by name: the uniform per-mode policies plus the
    /// optional manifest `policies` section, resolved and validated at load.
    pub policies: BTreeMap<String, PolicySpec>,
    /// The `PolicyId` space: `mode_order` first (uniform policies share
    /// indices with `ModeId`), then the `policies` section in declaration
    /// order.
    pub policy_order: Vec<String>,
    pub micro: BTreeMap<String, String>,
}

fn parse_specs(v: &Value) -> Result<Vec<ParamSpec>> {
    let mut out = Vec::new();
    for item in v.as_array().context("params not an array")? {
        let t = item.as_array().context("param spec not an array")?;
        if t.len() != 3 {
            bail!("param spec must be [name, shape, dtype]");
        }
        let shape = t[1]
            .as_array()
            .context("shape")?
            .iter()
            .map(|d| d.as_usize().context("dim"))
            .collect::<Result<Vec<_>>>()?;
        out.push(ParamSpec {
            name: t[0].as_str().context("name")?.to_string(),
            shape,
            dtype: DType::from_manifest(t[2].as_str().context("dtype")?)?,
        });
    }
    Ok(out)
}

fn get_usize(v: &Value, key: &str) -> Result<usize> {
    v.req(key)?.as_usize().with_context(|| format!("{key} not a number"))
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        Self::from_json_str(&src, artifacts_dir).with_context(|| format!("{path:?}"))
    }

    /// Hot-reload compatibility gate (DESIGN.md §5.13): a reloaded
    /// manifest may change weights and artifact paths, but the interned
    /// ID spaces and the (seq, batch) grid axes must be identical —
    /// `TaskId`/`ModeId`/`PolicyId` values, governor chains, and bucket
    /// indices are shared across versions, so any drift here would
    /// silently misroute in-flight work.  Incompatible manifests need a
    /// restart, not a reload.
    pub fn grid_compatible(&self, other: &Manifest) -> Result<()> {
        if self.mode_order != other.mode_order {
            bail!(
                "reload changes mode_order ({:?} -> {:?}); restart required",
                self.mode_order,
                other.mode_order
            );
        }
        if self.policy_order != other.policy_order {
            bail!(
                "reload changes policy_order ({:?} -> {:?}); restart required",
                self.policy_order,
                other.policy_order
            );
        }
        if self.task_order != other.task_order {
            bail!(
                "reload changes task_order ({:?} -> {:?}); restart required",
                self.task_order,
                other.task_order
            );
        }
        if self.buckets != other.buckets {
            bail!(
                "reload changes batch buckets ({:?} -> {:?}); restart required",
                self.buckets,
                other.buckets
            );
        }
        if self.seq_buckets != other.seq_buckets {
            bail!(
                "reload changes seq buckets ({:?} -> {:?}); restart required",
                self.seq_buckets,
                other.seq_buckets
            );
        }
        if self.seq != other.seq {
            bail!("reload changes seq ({} -> {}); restart required", self.seq, other.seq);
        }
        if self.model.num_labels != other.model.num_labels {
            bail!(
                "reload changes num_labels ({} -> {}); restart required",
                self.model.num_labels,
                other.model.num_labels
            );
        }
        Ok(())
    }

    /// Parse a manifest from JSON source — the file-less entry point the
    /// validation tests use to exercise error paths (bad policies, bad
    /// modes) without a generated artifacts dir.
    pub fn from_json_str(src: &str, artifacts_dir: &Path) -> Result<Self> {
        let v = json::parse(src).map_err(|e| anyhow::anyhow!("{e}"))?;

        let m = v.req("model")?;
        let model = ModelCfg {
            vocab_size: get_usize(m, "vocab_size")?,
            hidden: get_usize(m, "hidden")?,
            layers: get_usize(m, "layers")?,
            heads: get_usize(m, "heads")?,
            ffn: get_usize(m, "ffn")?,
            max_seq: get_usize(m, "max_seq")?,
            type_vocab: get_usize(m, "type_vocab")?,
            num_labels: get_usize(m, "num_labels")?,
            ln_eps: m.req("ln_eps")?.as_f64().context("ln_eps")?,
        };

        let buckets = v
            .req("buckets")?
            .as_array()
            .context("buckets")?
            .iter()
            .map(|b| b.as_usize().context("bucket"))
            .collect::<Result<Vec<_>>>()?;
        // `bucket_for`'s first-fit scan and the serving-side max_batch
        // validation both read `buckets.last()` as the largest — enforce
        // the ordering here rather than assuming it
        if !buckets.windows(2).all(|w| w[0] < w[1]) {
            bail!("buckets must be strictly ascending (got {buckets:?})");
        }

        // the sequence axis is needed before the modes: artifact keys
        // resolve against it (a bare "bN" key means (seq, N))
        let seq = get_usize(&v, "seq")?;
        let seq_buckets = match v.get("seq_buckets") {
            // format_version 2 (and earlier): one implicit bucket — the
            // full sequence length, exactly the pre-grid behaviour
            None => vec![seq],
            Some(sv) => {
                let sb = sv
                    .as_array()
                    .context("seq_buckets not an array")?
                    .iter()
                    .map(|b| b.as_usize().context("seq bucket"))
                    .collect::<Result<Vec<_>>>()?;
                if sb.is_empty() {
                    bail!("seq_buckets must not be empty");
                }
                if !sb.windows(2).all(|w| w[0] < w[1]) {
                    bail!("seq_buckets must be strictly ascending (got {sb:?})");
                }
                if *sb.last().expect("non-empty") != seq {
                    bail!(
                        "largest seq bucket {} != seq {seq} (every admissible request \
                         must fit the top bucket)",
                        sb.last().expect("non-empty")
                    );
                }
                sb
            }
        };

        let mut modes = BTreeMap::new();
        let mut mode_order = Vec::new();
        for (name, mv) in v.req("modes")?.as_object().context("modes")? {
            let swv = mv.req("switches")?;
            let flag = |k: &str| -> Result<bool> {
                swv.req(k)?.as_bool().with_context(|| format!("switch {k}"))
            };
            let switches = Switches {
                embedding: flag("embedding")?,
                qkv: flag("qkv")?,
                attn: flag("attn")?,
                attn_output: flag("attn_output")?,
                fc1: flag("fc1")?,
                fc2: flag("fc2")?,
            };
            let mut artifacts = BTreeMap::new();
            for (bk, pv) in mv.req("artifacts")?.as_object().context("artifacts")? {
                // grid key "s<seq>b<batch>" (format_version 3) or legacy
                // "b<batch>" (format_version 2), which pins the full seq
                let cell: (usize, usize) = if let Some(rest) = bk.strip_prefix('s') {
                    let (s, b) = rest
                        .split_once('b')
                        .with_context(|| format!("bad artifact key {bk} (want sNbM)"))?;
                    (
                        s.parse().with_context(|| format!("bad seq in artifact key {bk}"))?,
                        b.parse().with_context(|| format!("bad batch in artifact key {bk}"))?,
                    )
                } else {
                    let bucket: usize = bk
                        .strip_prefix('b')
                        .and_then(|s| s.parse().ok())
                        .with_context(|| format!("bad bucket key {bk}"))?;
                    (seq, bucket)
                };
                if !seq_buckets.contains(&cell.0) {
                    bail!(
                        "artifact key {bk}: seq bucket {} not in seq_buckets {seq_buckets:?}",
                        cell.0
                    );
                }
                if !buckets.contains(&cell.1) {
                    bail!(
                        "artifact key {bk}: batch bucket {} not in buckets {buckets:?}",
                        cell.1
                    );
                }
                let path = pv.as_str().context("artifact path")?.to_string();
                if artifacts.insert(cell, path).is_some() {
                    // a legacy "bN" and a grid "sSbN" key can collide on
                    // the same cell; last-wins would silently serve one
                    // of two conflicting artifacts
                    bail!(
                        "artifact key {bk}: duplicate cell (seq {}, bucket {})",
                        cell.0,
                        cell.1
                    );
                }
            }
            mode_order.push(name.clone());
            modes.insert(
                name.clone(),
                ModeSpec {
                    name: name.clone(),
                    switches,
                    params: parse_specs(mv.req("params")?)?,
                    artifacts,
                },
            );
        }

        let cv = v.req("calib")?;
        let mut stats = Vec::new();
        for item in cv.req("stats")?.as_array().context("stats")? {
            let t = item.as_array().context("stat spec")?;
            let shape = t[1]
                .as_array()
                .context("stat shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<Vec<_>>>()?;
            stats.push((t[0].as_str().context("stat name")?.to_string(), shape));
        }
        let calib = CalibSpec {
            artifact: cv.req("artifact")?.as_str().context("calib artifact")?.to_string(),
            batch: get_usize(cv, "batch")?,
            params: parse_specs(cv.req("params")?)?,
            stats,
        };

        let mut tasks = BTreeMap::new();
        let mut task_order = Vec::new();
        for (name, tv) in v.req("tasks")?.as_object().context("tasks")? {
            let mut splits = BTreeMap::new();
            for (sn, sv) in tv.req("splits")?.as_object().context("splits")? {
                splits.insert(sn.clone(), sv.as_str().context("split path")?.to_string());
            }
            let metrics = tv
                .req("metrics")?
                .as_array()
                .context("metrics")?
                .iter()
                .map(|x| x.as_str().map(str::to_string).context("metric"))
                .collect::<Result<Vec<_>>>()?;
            task_order.push(name.clone());
            tasks.insert(
                name.clone(),
                TaskSpec {
                    name: name.clone(),
                    classes: get_usize(tv, "classes")?,
                    metrics,
                    splits,
                    checkpoint: tv.req("checkpoint")?.as_str().context("checkpoint")?.to_string(),
                },
            );
        }

        let mut micro = BTreeMap::new();
        if let Some(mv) = v.get("micro").and_then(|x| x.as_object()) {
            for (k, pv) in mv {
                if let Some(p) = pv.as_str() {
                    micro.insert(k.clone(), p.to_string());
                }
            }
        }

        let mut man = Manifest {
            root: artifacts_dir.to_path_buf(),
            model,
            seq,
            seq_buckets,
            buckets,
            modes,
            mode_order,
            calib,
            tasks,
            task_order,
            policies: BTreeMap::new(),
            policy_order: Vec::new(),
            micro,
        };
        man.init_policies(v.get("policies"))?;
        Ok(man)
    }

    /// Build the policy table: one uniform policy per mode (sharing the
    /// mode's dense index), then the optional `policies` section resolved
    /// against `mode_order`.  All validation happens here, at load — a
    /// manifest policy can never fail at admission.
    fn init_policies(&mut self, section: Option<&Value>) -> Result<()> {
        let mut order = Vec::with_capacity(self.mode_order.len());
        let mut table = BTreeMap::new();
        for (i, name) in self.mode_order.iter().enumerate() {
            let sw = self.modes[name].switches;
            table.insert(name.clone(), PolicySpec::uniform(name, ModeId(i as u16), sw));
            order.push(name.clone());
        }
        if let Some(sec) = section {
            for (name, pv) in sec.as_object().context("policies not an object")? {
                if self.modes.contains_key(name) {
                    bail!("policy {name:?} shadows the mode of the same name");
                }
                if table.contains_key(name) {
                    bail!("duplicate policy {name:?}");
                }
                let draft = PolicyDraft::from_json(pv)
                    .with_context(|| format!("policy {name:?}"))?;
                let spec = self.resolve_policy(name, &draft)?;
                order.push(name.clone());
                table.insert(name.clone(), spec);
            }
        }
        self.policies = table;
        self.policy_order = order;
        Ok(())
    }

    /// Validate a draft against this manifest and pick its executable
    /// mode: the mode whose switches equal the effective (base +
    /// overrides) set, else the first fallback mode that only escalates
    /// precision (`Switches::subset_of`), else an error.
    pub fn resolve_policy(&self, name: &str, draft: &PolicyDraft) -> Result<PolicySpec> {
        let base = self
            .mode_id(&draft.base)
            .with_context(|| format!("policy {name:?}: bad base mode"))?;
        let mut effective = self.mode_by_id(base).switches;
        let mut overrides = Vec::with_capacity(draft.overrides.len());
        for (g, p) in &draft.overrides {
            let group = ModuleGroup::parse(g)
                .with_context(|| format!("policy {name:?}: bad override group"))?;
            let prec = ModulePrecision::parse(p)
                .with_context(|| format!("policy {name:?}: bad override precision"))?;
            effective.set(group, prec == ModulePrecision::Int8);
            overrides.push((group, prec));
        }
        let mut fallback = Vec::with_capacity(draft.fallback.len());
        for m in &draft.fallback {
            fallback.push(
                self.mode_id(m)
                    .with_context(|| format!("policy {name:?}: bad fallback mode"))?,
            );
        }
        let exec_mode = self.exec_mode_for(effective, &fallback).with_context(|| {
            format!(
                "policy {name:?}: no mode artifact matches switches {} and no fallback \
                 escalates (fallback {:?}, modes {:?})",
                effective.tag(),
                draft.fallback,
                self.mode_order
            )
        })?;
        Ok(PolicySpec {
            name: name.to_string(),
            base,
            overrides,
            fallback,
            effective,
            exec_mode,
        })
    }

    fn exec_mode_for(&self, effective: Switches, fallback: &[ModeId]) -> Option<ModeId> {
        for (i, name) in self.mode_order.iter().enumerate() {
            if self.modes[name].switches == effective {
                return Some(ModeId(i as u16));
            }
        }
        fallback
            .iter()
            .copied()
            .find(|m| self.mode_by_id(*m).switches.subset_of(&effective))
    }

    /// Intern an inline (wire v2) draft into the fixed `PolicyId` space:
    /// an identical manifest policy wins (stats attribute to its name),
    /// else the uniform policy of the draft's executable mode — identical
    /// execution, and the id space never grows after load.
    pub fn intern_inline_policy(&self, draft: &PolicyDraft) -> Result<PolicyId> {
        let spec = self.resolve_policy("<inline>", draft)?;
        for (i, name) in self.policy_order.iter().enumerate() {
            let p = &self.policies[name];
            if p.base == spec.base && p.overrides == spec.overrides && p.fallback == spec.fallback
            {
                return Ok(PolicyId(i as u16));
            }
        }
        Ok(PolicyId(spec.exec_mode.0))
    }

    /// The overload-degradation chain of a policy (DESIGN.md §5.8): the
    /// uniform policies of every mode in `fallback ∪ {base}` that is
    /// *strictly cheaper* than the policy's executable mode (its INT8 set
    /// strictly contains the exec mode's — the mirror image of §6.1's
    /// escalation rule, which only raises precision), ordered
    /// closest-first (ascending INT8 count) so "one step down" sacrifices
    /// the least accuracy for speed.  Uniform policies have no fallback
    /// chain and therefore an empty degradation chain — the governor
    /// never invents precision trades the policy author did not declare.
    pub fn downgrade_chain(&self, id: PolicyId) -> Vec<PolicyId> {
        let spec = self.policy_by_id(id);
        let exec_sw = self.mode_by_id(spec.exec_mode).switches;
        let mut modes: Vec<ModeId> = spec
            .fallback
            .iter()
            .copied()
            .chain(std::iter::once(spec.base))
            .filter(|m| {
                let sw = self.mode_by_id(*m).switches;
                sw != exec_sw && exec_sw.subset_of(&sw)
            })
            .collect();
        modes.sort_by_key(|m| self.mode_by_id(*m).switches.int8_count());
        modes.dedup();
        // uniform per-mode policies share the mode's dense index (§6.3)
        modes.into_iter().map(|m| PolicyId(m.0)).collect()
    }

    pub fn mode(&self, name: &str) -> Result<&ModeSpec> {
        self.modes
            .get(name)
            .with_context(|| format!("unknown mode {name:?} (have {:?})", self.mode_order))
    }

    pub fn task(&self, name: &str) -> Result<&TaskSpec> {
        self.tasks
            .get(name)
            .with_context(|| format!("unknown task {name:?} (have {:?})", self.task_order))
    }

    // ------------------------------------------------------ route interning

    pub fn num_tasks(&self) -> usize {
        self.task_order.len()
    }

    pub fn num_modes(&self) -> usize {
        self.mode_order.len()
    }

    pub fn num_policies(&self) -> usize {
        self.policy_order.len()
    }

    pub fn num_buckets(&self) -> usize {
        self.buckets.len()
    }

    pub fn num_seq_buckets(&self) -> usize {
        self.seq_buckets.len()
    }

    /// Resolve a task name to its dense id (position in `task_order`).
    pub fn task_id(&self, name: &str) -> Result<TaskId> {
        intern_position(&self.task_order, name)
            .map(TaskId)
            .with_context(|| format!("unknown task {name:?} (have {:?})", self.task_order))
    }

    /// Resolve a mode name to its dense id (position in `mode_order`).
    pub fn mode_id(&self, name: &str) -> Result<ModeId> {
        intern_position(&self.mode_order, name)
            .map(ModeId)
            .with_context(|| format!("unknown mode {name:?} (have {:?})", self.mode_order))
    }

    /// Resolve a policy name (uniform mode names included) to its dense id.
    pub fn policy_id(&self, name: &str) -> Result<PolicyId> {
        intern_position(&self.policy_order, name)
            .map(PolicyId)
            .with_context(|| format!("unknown policy {name:?} (have {:?})", self.policy_order))
    }

    pub fn task_name(&self, id: TaskId) -> &str {
        &self.task_order[id.index()]
    }

    pub fn mode_name(&self, id: ModeId) -> &str {
        &self.mode_order[id.index()]
    }

    pub fn task_by_id(&self, id: TaskId) -> &TaskSpec {
        &self.tasks[&self.task_order[id.index()]]
    }

    pub fn mode_by_id(&self, id: ModeId) -> &ModeSpec {
        &self.modes[&self.mode_order[id.index()]]
    }

    pub fn policy_name(&self, id: PolicyId) -> &str {
        &self.policy_order[id.index()]
    }

    pub fn policy_by_id(&self, id: PolicyId) -> &PolicySpec {
        &self.policies[&self.policy_order[id.index()]]
    }

    pub fn policy(&self, name: &str) -> Result<&PolicySpec> {
        self.policies
            .get(name)
            .with_context(|| format!("unknown policy {name:?} (have {:?})", self.policy_order))
    }

    /// Dense index of an exact bucket size (for `Vec`-indexed exe tables).
    pub fn bucket_index(&self, bucket: usize) -> Result<usize> {
        self.buckets
            .iter()
            .position(|b| *b == bucket)
            .with_context(|| format!("bucket {bucket} not in manifest buckets {:?}", self.buckets))
    }

    /// Dense index of an exact seq bucket (for `Vec`-indexed exe tables).
    pub fn seq_bucket_index(&self, seq_bucket: usize) -> Result<usize> {
        self.seq_buckets.iter().position(|b| *b == seq_bucket).with_context(|| {
            format!("seq bucket {seq_bucket} not in manifest seq_buckets {:?}", self.seq_buckets)
        })
    }

    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }

    /// Smallest bucket >= n, or the largest bucket if n exceeds all.
    /// NB: the clamp exists for cold-path convenience only — serving
    /// validates `max_batch` against the largest bucket at startup
    /// (`ServerConfig` / `ConfigError`), so a dispatched batch never
    /// silently shrinks through here.
    pub fn bucket_for(&self, n: usize) -> usize {
        for b in &self.buckets {
            if *b >= n {
                return *b;
            }
        }
        *self.buckets.last().expect("no buckets")
    }

    /// Smallest seq bucket >= n tokens, or the largest if n exceeds all
    /// (admission bounds request length by `seq`, the top bucket, so the
    /// fallback only triggers for cold-path callers).
    pub fn seq_bucket_for(&self, n: usize) -> usize {
        for b in &self.seq_buckets {
            if *b >= n {
                return *b;
            }
        }
        *self.seq_buckets.last().expect("no seq buckets")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bare_manifest() -> Manifest {
        Manifest {
            root: PathBuf::new(),
            model: ModelCfg {
                vocab_size: 1, hidden: 1, layers: 1, heads: 1, ffn: 1,
                max_seq: 1, type_vocab: 1, num_labels: 1, ln_eps: 1e-12,
            },
            seq: 128,
            seq_buckets: vec![16, 32, 64, 128],
            buckets: vec![1, 4, 8, 16],
            modes: BTreeMap::new(),
            mode_order: vec![],
            calib: CalibSpec { artifact: String::new(), batch: 16, params: vec![], stats: vec![] },
            tasks: BTreeMap::new(),
            task_order: vec![],
            policies: BTreeMap::new(),
            policy_order: vec![],
            micro: BTreeMap::new(),
        }
    }

    #[test]
    fn bucket_for_picks_smallest_fit() {
        let man = bare_manifest();
        assert_eq!(man.bucket_for(1), 1);
        assert_eq!(man.bucket_for(2), 4);
        assert_eq!(man.bucket_for(4), 4);
        assert_eq!(man.bucket_for(9), 16);
        assert_eq!(man.bucket_for(99), 16);
    }

    #[test]
    fn seq_bucket_for_picks_smallest_fit_and_indexes() {
        let man = bare_manifest();
        assert_eq!(man.seq_bucket_for(1), 16);
        assert_eq!(man.seq_bucket_for(16), 16);
        assert_eq!(man.seq_bucket_for(17), 32);
        assert_eq!(man.seq_bucket_for(100), 128);
        // cold-path clamp, same contract as bucket_for
        assert_eq!(man.seq_bucket_for(999), 128);
        assert_eq!(man.seq_bucket_index(64).unwrap(), 2);
        assert!(man.seq_bucket_index(65).is_err());
        assert_eq!(man.num_seq_buckets(), 4);

        // single-bucket axis (format_version 2 fallback shape): every
        // length lands on the full seq, the pre-grid behaviour
        let mut man = bare_manifest();
        man.seq_buckets = vec![128];
        assert_eq!(man.seq_bucket_for(1), 128);
        assert_eq!(man.seq_bucket_for(128), 128);
        assert_eq!(man.seq_bucket_index(128).unwrap(), 0);
    }

    #[test]
    fn route_ids_are_dense_and_roundtrip() {
        let mut man = bare_manifest();
        man.mode_order = vec!["fp".into(), "m1".into(), "m3".into()];
        man.task_order = vec!["cola".into(), "sst2".into()];
        assert_eq!(man.task_id("sst2").unwrap(), TaskId(1));
        assert_eq!(man.mode_id("m3").unwrap(), ModeId(2));
        assert_eq!(man.task_name(TaskId(1)), "sst2");
        assert_eq!(man.mode_name(ModeId(0)), "fp");
        assert!(man.task_id("nope").is_err());
        assert!(man.mode_id("m9").is_err());
        assert_eq!(man.bucket_index(8).unwrap(), 2);
        assert!(man.bucket_index(5).is_err());
        assert_eq!(man.num_tasks(), 2);
        assert_eq!(man.num_modes(), 3);
    }

    #[test]
    fn switches_tag() {
        let mut sw = Switches::ALL_OFF;
        sw.embedding = true;
        sw.fc1 = true;
        assert_eq!(sw.tag(), "100010");
    }

    #[test]
    fn switches_groups_and_subset() {
        let mut sw = Switches::ALL_OFF;
        sw.set(ModuleGroup::Qkv, true);
        sw.set(ModuleGroup::Fc2, true);
        assert!(sw.get(ModuleGroup::Qkv) && sw.get(ModuleGroup::Fc2));
        assert!(!sw.get(ModuleGroup::Attn));
        assert_eq!(sw.tag(), "010001");

        let mut wider = sw;
        wider.set(ModuleGroup::Attn, true);
        assert!(sw.subset_of(&wider));
        assert!(!wider.subset_of(&sw));
        assert!(Switches::ALL_OFF.subset_of(&sw));
    }

    #[test]
    fn module_group_parse_round_trips_and_rejects() {
        for g in ModuleGroup::ALL.iter().copied() {
            assert_eq!(ModuleGroup::parse(g.name()).unwrap(), g);
        }
        let err = ModuleGroup::parse("fc9").unwrap_err().to_string();
        assert!(err.contains("unknown module group") && err.contains("attn_output"), "{err}");
        assert_eq!(ModulePrecision::parse("fp16").unwrap(), ModulePrecision::Fp);
        assert_eq!(ModulePrecision::parse("int8").unwrap(), ModulePrecision::Int8);
        assert!(ModulePrecision::parse("int4").is_err());
    }

    #[test]
    fn policy_draft_json_round_trip() {
        let draft = PolicyDraft::base("m3")
            .with_override("attn_output", "fp")
            .with_fallback("m1")
            .with_fallback("fp");
        let parsed = PolicyDraft::from_json(&draft.to_json()).unwrap();
        assert_eq!(parsed, draft);
        // minimal form: base only
        let minimal = PolicyDraft::base("fp");
        assert_eq!(PolicyDraft::from_json(&minimal.to_json()).unwrap(), minimal);
        // malformed: missing base / non-pair override
        assert!(PolicyDraft::from_json(&json::parse(r#"{}"#).unwrap()).is_err());
        let bad = json::parse(r#"{"base": "m3", "overrides": [["qkv"]]}"#).unwrap();
        assert!(PolicyDraft::from_json(&bad).is_err());
        // misspelled key must error, not silently drop the overrides
        let typo = json::parse(r#"{"base": "m3", "override": [["qkv", "fp"]]}"#).unwrap();
        let err = PolicyDraft::from_json(&typo).unwrap_err().to_string();
        assert!(err.contains("unknown policy key"), "{err}");
    }

    #[test]
    fn grid_compatible_accepts_same_grid_and_rejects_drift() {
        let a = bare_manifest();
        let b = bare_manifest();
        a.grid_compatible(&b).unwrap();
        // weights/artifact-path changes are invisible to the grid gate
        let mut c = bare_manifest();
        c.root = PathBuf::from("/elsewhere");
        a.grid_compatible(&c).unwrap();
        // any axis or interning drift is a restart, not a reload
        let mut d = bare_manifest();
        d.seq_buckets = vec![16, 32, 128];
        let err = a.grid_compatible(&d).unwrap_err().to_string();
        assert!(err.contains("seq buckets"), "{err}");
        let mut e = bare_manifest();
        e.mode_order = vec!["fp".into()];
        assert!(a.grid_compatible(&e).is_err());
        let mut f = bare_manifest();
        f.policy_order = vec!["fp".into()];
        assert!(a.grid_compatible(&f).is_err());
        let mut g = bare_manifest();
        g.model.num_labels = 3;
        assert!(a.grid_compatible(&g).is_err());
    }

    #[test]
    fn task_checkpoint_rel_per_mode() {
        let task = TaskSpec {
            name: "sst2".into(),
            classes: 2,
            metrics: vec!["acc".into()],
            splits: BTreeMap::new(),
            checkpoint: "checkpoints/sst2/fp32.bin".into(),
        };
        assert_eq!(task.checkpoint_rel("fp"), "checkpoints/sst2/fp32.bin");
        assert_eq!(task.checkpoint_rel("m3"), "checkpoints/sst2/hero-m3.bin");
    }
}
