"""``Softmax^quant`` (paper eq. 16): row softmax with *asymmetric* INT8
output (zero point -128 — softmax is non-negative so the full 255-level
range is used), as a standalone Pallas kernel.

Inside the fused attention core (attention_quant.py) the same math is
inlined; this standalone kernel exists for unit testing, the fig-1 precision
trace, and the micro-benchmarks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick(n, want=256):
    b = min(n, want)
    while n % b:
        b -= 1
    return b


def softmax_rows(a):
    a = a - jnp.max(a, axis=-1, keepdims=True)
    e = jnp.exp(a)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def quantize_p(p, s_p):
    """p in [0,1] -> asymmetric int8 with zero point -128."""
    q = jnp.round(p / s_p) - 128.0
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def _softmax_quant_kernel(a_ref, sp_ref, q_ref):
    p = softmax_rows(a_ref[...])
    q_ref[...] = quantize_p(p, sp_ref[0, 0])


def softmax_quant(a, s_p, *, block_rows=None):
    """f32 [r, n] (mask already applied) -> asym int8 [r, n]."""
    r, n = a.shape
    br = block_rows or _pick(r)
    sp = jnp.asarray(s_p, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _softmax_quant_kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((r, n), jnp.int8)],
        interpret=True,
    )(a, sp)[0]
