//! Precision-flow traces for Figures 1 and 2: the tensor-by-tensor
//! quantization annotations of the attention and MLP modules, generated
//! from a switch set and *verified against the lowered HLO* (an INT8 GeMM
//! accumulates in s32, so the number of `s32 dot` instructions in the
//! artifact must match what the mode claims — Table 1 made checkable).

use anyhow::{Context, Result};

use crate::model::manifest::{Manifest, Switches};

#[derive(Debug, Clone)]
pub struct FlowRow {
    pub tensor: &'static str,
    pub producer: &'static str,
    pub scheme: String,
    pub dtype: String,
}

fn row(tensor: &'static str, producer: &'static str, scheme: &str, dtype: &str) -> FlowRow {
    FlowRow { tensor, producer, scheme: scheme.into(), dtype: dtype.into() }
}

/// Figure 1: attention module dataflow under a switch set.
pub fn attention_flow(sw: &Switches) -> Vec<FlowRow> {
    let mut rows = Vec::new();
    if sw.qkv {
        rows.push(row("X_in", "LN^quant (prev)", "TWQ", "int8"));
    } else {
        rows.push(row("X_in", "LN (prev)", "none", "fp"));
    }
    if sw.attn {
        rows.push(row("X_q/k/v", "GeMM^quant + Round", "SQ", "int8"));
        rows.push(row("A", "GeMM^quant(QK^T, folded SqSk/sqrt(d))", "none", "fp"));
        rows.push(row("P", "Softmax^quant", "SQ asym (zp=-128)", "int8"));
        rows.push(row("X_attn", "GeMM^quant(PV)", "FWQ", "int8"));
    } else {
        let prod = if sw.qkv { "GeMM^quant (dequant epilogue)" } else { "GeMM" };
        rows.push(row("X_q/k/v", prod, "none", "fp"));
        rows.push(row("A", "QK^T / sqrt(d)", "none", "fp"));
        rows.push(row("P", "Softmax", "none", "fp"));
        rows.push(row("X_attn", "PV", "none", "fp"));
    }
    if sw.attn_output {
        rows.push(row("X_o", "GeMM^quant(W~_o, eq.23) + Round", "FWQ", "int8"));
    } else {
        rows.push(row("X_o", "GeMM(W_o)", "none", "fp"));
    }
    if sw.fc1 {
        rows.push(row("X_out", "LN^quant", "TWQ", "int8"));
    } else {
        rows.push(row("X_out", "LN", "none", "fp"));
    }
    rows
}

/// Figure 2: MLP module dataflow under a switch set.
pub fn mlp_flow(sw: &Switches) -> Vec<FlowRow> {
    let mut rows = Vec::new();
    if sw.fc1 {
        rows.push(row("X_in", "LN^quant", "TWQ", "int8"));
        rows.push(row("X_1", "GeMM^quant (dequant epilogue)", "none", "fp"));
    } else {
        rows.push(row("X_in", "LN", "none", "fp"));
        rows.push(row("X_1", "GeMM(W_1)", "none", "fp"));
    }
    if sw.fc2 {
        rows.push(row("A", "GELU^quant", "FWQ", "int8"));
        rows.push(row("X_2", "GeMM^quant(W~_2, eq.32) + Round", "FWQ", "int8"));
    } else {
        rows.push(row("A", "GELU", "none", "fp"));
        rows.push(row("X_2", "GeMM(W_2)", "none", "fp"));
    }
    rows.push(if sw.qkv {
        row("X_out", "LN^quant", "TWQ", "int8")
    } else {
        row("X_out", "LN", "none", "fp")
    });
    rows
}

// --------------------------------------------------------- HLO verification

/// Expected number of `s32`-accumulating dot instructions per layer for a
/// switch set (INT8 GeMMs accumulate in int32; FP GeMMs are f32 dots).
pub fn expected_int8_dots_per_layer(sw: &Switches) -> usize {
    let mut n = 0;
    if sw.qkv {
        n += 3;
    }
    if sw.attn {
        n += 2; // QK^T and PV
    }
    if sw.attn_output {
        n += 1;
    }
    if sw.fc1 {
        n += 1;
    }
    if sw.fc2 {
        n += 1;
    }
    n
}

/// Count `= s32[...] dot(` instructions in HLO text.
pub fn count_int8_dots(hlo_text: &str) -> usize {
    hlo_text
        .lines()
        .filter(|l| {
            if let Some(eq) = l.find("= s32[") {
                l[eq..].contains(" dot(")
            } else {
                false
            }
        })
        .count()
}

/// Verify a mode's artifact matches its Table-1 row.  Returns
/// (expected, found).
pub fn verify_mode_artifact(man: &Manifest, mode: &str, bucket: usize) -> Result<(usize, usize)> {
    let spec = man.mode(mode)?;
    // trace verification reads the full-seq cell of the (seq, batch) grid
    let rel = spec
        .artifacts
        .get(&(man.seq, bucket))
        .with_context(|| format!("mode {mode} missing (seq {}, bucket {bucket})", man.seq))?;
    let text = std::fs::read_to_string(man.path(rel))?;
    let expected = expected_int8_dots_per_layer(&spec.switches) * man.model.layers;
    Ok((expected, count_int8_dots(&text)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sw(tag: &str) -> Switches {
        let b: Vec<bool> = tag.chars().map(|c| c == '1').collect();
        Switches {
            embedding: b[0],
            qkv: b[1],
            attn: b[2],
            attn_output: b[3],
            fc1: b[4],
            fc2: b[5],
        }
    }

    #[test]
    fn dot_counts_per_mode() {
        assert_eq!(expected_int8_dots_per_layer(&sw("000000")), 0);
        assert_eq!(expected_int8_dots_per_layer(&sw("110010")), 4); // M1
        assert_eq!(expected_int8_dots_per_layer(&sw("111110")), 7); // M2
        assert_eq!(expected_int8_dots_per_layer(&sw("111111")), 8); // M3
    }

    #[test]
    fn hlo_counter_matches_pattern() {
        let hlo = "\
  %dot.1 = s32[16,128]{1,0} dot(%convert.2, %convert.3), lhs_contracting_dims={1}
  %dot.2 = f32[16,128]{1,0} dot(%p1, %p2), lhs_contracting_dims={1}
  %add.9 = s32[16,128]{1,0} add(%dot.1, %dot.1)
  dot.5 = s32[4,4]{1,0} dot(convert.9, convert.10)
";
        assert_eq!(count_int8_dots(hlo), 2);
    }

    #[test]
    fn m3_attention_flow_matches_paper() {
        // paper §2.2.2: TWQ for X_in/X_out, SQ for q/k/v/P, FWQ X_attn/X_o,
        // A unquantized.
        let rows = attention_flow(&sw("111111"));
        let find = |t: &str| rows.iter().find(|r| r.tensor == t).unwrap();
        assert_eq!(find("X_in").scheme, "TWQ");
        assert_eq!(find("X_q/k/v").scheme, "SQ");
        assert_eq!(find("A").dtype, "fp");
        assert!(find("P").scheme.contains("asym"));
        assert_eq!(find("X_attn").scheme, "FWQ");
        assert_eq!(find("X_o").scheme, "FWQ");
        assert_eq!(find("X_out").scheme, "TWQ");
    }

    #[test]
    fn m3_mlp_flow_matches_paper() {
        // paper §2.2.3: X_1 unquantized, A and X_2 FWQ.
        let rows = mlp_flow(&sw("111111"));
        let find = |t: &str| rows.iter().find(|r| r.tensor == t).unwrap();
        assert_eq!(find("X_1").dtype, "fp");
        assert_eq!(find("A").scheme, "FWQ");
        assert_eq!(find("X_2").scheme, "FWQ");
    }
}
