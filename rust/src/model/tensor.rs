//! Host-side tensor: the common currency between the checkpoint container,
//! the quantization engine and the PJRT literal marshalling.

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I8,
    I32,
}

impl DType {
    pub fn code(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I8 => 1,
            DType::I32 => 2,
        }
    }

    pub fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => DType::F32,
            1 => DType::I8,
            2 => DType::I32,
            _ => bail!("unknown dtype code {c}"),
        })
    }

    pub fn from_manifest(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "i8" => DType::I8,
            "i32" => DType::I32,
            _ => bail!("unknown manifest dtype {s:?}"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 => 1,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I8(Vec<i8>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::F32(data) }
    }

    pub fn i8(shape: Vec<usize>, data: Vec<i8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::I8(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::I32(data) }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor::f32(vec![1], vec![v])
    }

    pub fn zeros(dtype: DType, shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        match dtype {
            DType::F32 => Tensor::f32(shape, vec![0.0; n]),
            DType::I8 => Tensor::i8(shape, vec![0; n]),
            DType::I32 => Tensor::i32(shape, vec![0; n]),
        }
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I8(_) => DType::I8,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn nbytes(&self) -> usize {
        self.numel() * self.dtype().size()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor, got {:?}", self.dtype()),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match &self.data {
            TensorData::I8(v) => Ok(v),
            _ => bail!("expected i8 tensor, got {:?}", self.dtype()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("expected i32 tensor, got {:?}", self.dtype()),
        }
    }

    /// Raw little-endian bytes (for container IO and PJRT upload).
    pub fn raw_bytes(&self) -> Vec<u8> {
        match &self.data {
            TensorData::F32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            TensorData::I32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            TensorData::I8(v) => v.iter().map(|x| *x as u8).collect(),
        }
    }

    pub fn from_raw_bytes(dtype: DType, shape: Vec<usize>, raw: &[u8]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if raw.len() != n * dtype.size() {
            bail!("raw size {} != expected {}", raw.len(), n * dtype.size());
        }
        Ok(match dtype {
            DType::F32 => {
                let v = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Tensor::f32(shape, v)
            }
            DType::I32 => {
                let v = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Tensor::i32(shape, v)
            }
            DType::I8 => Tensor::i8(shape, raw.iter().map(|b| *b as i8).collect()),
        })
    }

    /// Row-major 2-D accessor helpers for the quantization engine.
    pub fn dim(&self, i: usize) -> usize {
        self.shape[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_roundtrip_f32() {
        let t = Tensor::f32(vec![2, 2], vec![1.0, -2.5, 3.5e-8, 4e9]);
        let r = Tensor::from_raw_bytes(DType::F32, vec![2, 2], &t.raw_bytes()).unwrap();
        assert_eq!(t, r);
    }

    #[test]
    fn raw_roundtrip_i8() {
        let t = Tensor::i8(vec![4], vec![-128, -1, 0, 127]);
        let r = Tensor::from_raw_bytes(DType::I8, vec![4], &t.raw_bytes()).unwrap();
        assert_eq!(t, r);
    }

    #[test]
    fn raw_roundtrip_i32() {
        let t = Tensor::i32(vec![3], vec![i32::MIN, 0, i32::MAX]);
        let r = Tensor::from_raw_bytes(DType::I32, vec![3], &t.raw_bytes()).unwrap();
        assert_eq!(t, r);
    }

    #[test]
    fn size_mismatch_rejected() {
        assert!(Tensor::from_raw_bytes(DType::F32, vec![2], &[0u8; 7]).is_err());
    }
}
