//! Chaos suite (DESIGN.md §5.10, §9): replica supervision driven end to
//! end on the fake engine device — no artifacts, no PJRT, a bare
//! checkout runs every test here.  Each test scripts failures through
//! the structured `FaultPlan` and asserts the supervision contract:
//! zero hung clients, exact ledger reconciliation
//! (admitted = completed + shed + expired + failed), dispatch-order
//! FIFO among survivors, capacity recovery after supervised restart,
//! and circuit-breaker terminal behavior.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use zqhero::coordinator::{Coordinator, RequestSpec, Response, ServerConfig, SubmitError};
use zqhero::runtime::{FaultKind, FaultPlan, FaultSpec, RestartPolicy};

/// A minimal-but-valid manifest for the fake engine: one mode, one
/// task, a tiny bucket grid, no artifacts on disk (artifact paths are
/// never opened under `fake_engine`).
const FAKE_MANIFEST: &str = r#"{
  "model": {"vocab_size": 64, "hidden": 8, "layers": 1, "heads": 2, "ffn": 16,
            "max_seq": 8, "type_vocab": 2, "num_labels": 3, "ln_eps": 0.00001},
  "seq": 8,
  "buckets": [1, 2, 4],
  "modes": {
    "fp": {
      "switches": {"embedding": false, "qkv": false, "attn": false,
                   "attn_output": false, "fc1": false, "fc2": false},
      "artifacts": {},
      "params": []
    }
  },
  "calib": {"artifact": "calib.bin", "batch": 1, "params": [], "stats": []},
  "tasks": {
    "chaos": {"splits": {}, "metrics": [], "classes": 3, "checkpoint": "ckpt-{mode}.bin"}
  }
}"#;

/// Degenerate manifest with an empty mode table: structurally valid,
/// but a request without an explicit policy has no default route.
const NO_MODES_MANIFEST: &str = r#"{
  "model": {"vocab_size": 64, "hidden": 8, "layers": 1, "heads": 2, "ffn": 16,
            "max_seq": 8, "type_vocab": 2, "num_labels": 3, "ln_eps": 0.00001},
  "seq": 8,
  "buckets": [1, 2, 4],
  "modes": {},
  "calib": {"artifact": "calib.bin", "batch": 1, "params": [], "stats": []},
  "tasks": {
    "chaos": {"splits": {}, "metrics": [], "classes": 3, "checkpoint": "ckpt-{mode}.bin"}
  }
}"#;

/// Write `manifest` into a per-test temp dir and return it (stable
/// within one test binary run; contents are overwritten, never reused).
fn fake_artifacts(test: &str, manifest: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("zqhero-chaos-{}-{test}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create fake artifacts dir");
    std::fs::write(dir.join("manifest.json"), manifest).expect("write fake manifest");
    dir
}

/// Base config for the suite: tiny batches, a fake device with a
/// deterministic per-batch latency, everything else default.
fn config(latency_ms: u64) -> ServerConfig {
    ServerConfig {
        max_batch: 2,
        max_wait: Duration::from_millis(1),
        queue_cap: 64,
        fake_engine: Some(Duration::from_millis(latency_ms)),
        ..ServerConfig::default()
    }
}

fn routes() -> Vec<(String, String)> {
    vec![("chaos".to_string(), "fp".to_string())]
}

fn spec(i: usize) -> RequestSpec {
    // vary the payload length across the seq range for realism; every
    // length lands in the single seq bucket (8)
    let len = 1 + i % 8;
    RequestSpec::task("chaos").mode("fp").ids((0..len as i32).collect())
}

/// Drain every receiver with a generous bound: a reply that never
/// arrives is precisely the hung-client bug the supervisor exists to
/// prevent, so the timeout is the test's core assertion.
fn drain(rxs: Vec<(u64, std::sync::mpsc::Receiver<Response>)>) -> Vec<Response> {
    rxs.into_iter()
        .map(|(id, rx)| {
            rx.recv_timeout(Duration::from_secs(30))
                .unwrap_or_else(|e| panic!("client hung waiting for request {id}: {e}"))
        })
        .collect()
}

/// Partition terminal outcomes; panics on any reply shape that violates
/// the outcome taxonomy (failed and expired are mutually exclusive;
/// completed replies carry logits, failed/expired ones never do).
struct Outcomes {
    completed: Vec<Response>,
    expired: usize,
    failed: usize,
}

fn classify(resps: Vec<Response>, num_labels: usize) -> Outcomes {
    let mut out = Outcomes { completed: Vec::new(), expired: 0, failed: 0 };
    for r in resps {
        assert!(!(r.failed && r.expired), "req {}: failed and expired at once", r.id);
        if r.failed {
            assert!(r.logits.is_empty(), "failed reply with logits");
            assert!(r.error.is_some(), "failed reply without an error");
            out.failed += 1;
        } else if r.expired {
            assert!(r.logits.is_empty(), "expired reply with logits");
            out.expired += 1;
        } else if let Some(e) = &r.error {
            panic!("unexpected generic error for req {}: {e}", r.id);
        } else {
            assert_eq!(r.logits.len(), num_labels, "req {}: bad logits width", r.id);
            out.completed.push(r);
        }
    }
    out
}

/// Wait (bounded) until `cond` holds; panics with `what` on timeout.
fn wait_until(what: &str, timeout: Duration, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < timeout, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn fake_engine_serves_end_to_end() {
    let dir = fake_artifacts("baseline", FAKE_MANIFEST);
    let coord = Coordinator::start(dir, &routes(), config(2)).unwrap();

    let mut rxs = Vec::new();
    for i in 0..20u64 {
        rxs.push((i, coord.submit(spec(i as usize)).expect("admit")));
    }
    let out = classify(drain(rxs), coord.num_labels());
    assert_eq!(out.completed.len(), 20);
    assert_eq!((out.failed, out.expired), (0, 0));

    let snap = coord.recorder.snapshot();
    let s = &snap["fp"];
    assert_eq!(s.requests, 20);
    assert_eq!(s.completed, 20);
    assert_eq!((s.errors, s.expired, s.failed, s.shed), (0, 0, 0, 0));
    assert_eq!(coord.queue_depth(), 0, "backlog slots leaked");
}

/// Satellite: a manifest whose mode table is empty must reject a
/// policy-less request with a typed `Rejected` at admission — not
/// fabricate an empty-string default mode that fails downstream with a
/// misleading "unknown mode" error.
#[test]
fn empty_manifest_submit_is_typed_rejection() {
    let dir = fake_artifacts("no-modes", NO_MODES_MANIFEST);
    let coord = Coordinator::start(dir, &[], config(1)).unwrap();
    let err = coord
        .submit(RequestSpec::task("chaos").ids(vec![1, 2, 3]))
        .expect_err("no-policy submit against a modeless manifest must be rejected");
    assert!(matches!(err, SubmitError::Rejected(_)), "wrong class: {err:?}");
    assert!(!err.is_busy());
    let msg = format!("{err}");
    assert!(msg.contains("no modes"), "unhelpful rejection: {msg}");
}

/// The tentpole scenario: a replica panics mid-batch under load.  Every
/// client gets a terminal reply (completed or typed `failed`), the
/// ledger reconciles exactly on both sides, dispatch FIFO holds among
/// survivors, the backlog drains to zero, and the supervisor restores
/// full capacity — after which new traffic completes cleanly.
#[test]
fn replica_panic_mid_batch_fails_over_and_reconciles() {
    let dir = fake_artifacts("panic", FAKE_MANIFEST);
    let coord = Coordinator::start(
        dir,
        &routes(),
        ServerConfig {
            replicas: 2,
            fault_plan: FaultPlan::default()
                .with(FaultSpec::on(0, FaultKind::PanicAt { batch: 1 })),
            ..config(5)
        },
    )
    .unwrap();
    assert_eq!(coord.engine().live_replicas(), 2);

    let total = 40u64;
    let mut rxs = Vec::new();
    for i in 0..total {
        rxs.push((i, coord.submit(spec(i as usize)).expect("queue_cap 64 admits all")));
    }
    let out = classify(drain(rxs), coord.num_labels());

    // zero hung clients, exact reconciliation: nothing shed (under cap),
    // nothing expired (no deadlines), so admitted = completed + failed
    assert_eq!(out.completed.len() + out.failed, total as usize);
    assert!(out.failed >= 1, "the panicked batch must fail its requests");
    assert!(!out.completed.is_empty(), "failover never completed anything");
    assert_eq!(coord.queue_depth(), 0, "backlog slots leaked through the failure");

    // recorder-side ledger agrees request for request
    let snap = coord.recorder.snapshot();
    let s = &snap["fp"];
    assert_eq!(s.requests, total);
    assert_eq!(s.completed as usize, out.completed.len());
    assert_eq!(s.failed as usize, out.failed);
    assert_eq!((s.errors, s.expired, s.shed), (0, 0, 0));
    assert_eq!(s.requests, s.completed + s.errors + s.expired + s.failed);

    // dispatch FIFO among survivors: ids are submit-ordered, so their
    // batch sequence numbers must be non-decreasing even across the
    // failover (orphans resubmit with their original dispatch order)
    let mut survivors = out.completed;
    survivors.sort_by_key(|r| r.id);
    let seqs: Vec<u64> = survivors.iter().map(|r| r.timing.batch_seq).collect();
    let mut sorted = seqs.clone();
    sorted.sort_unstable();
    assert_eq!(seqs, sorted, "survivors out of dispatch order");

    // supervised restart restores capacity: the watchdog-less supervisor
    // still detects thread death, and the respawned incarnation (its
    // non-persistent fault expired with generation 0) rejoins dispatch
    wait_until("replica 0 restart", Duration::from_secs(10), || {
        coord.engine().live_replicas() == 2
    });
    assert!(coord.engine().replica_restarts(0) >= 1);
    assert!(coord.engine().dispatch_state().generation(0) >= 1);
    assert!(!coord.engine().replica_excluded(0));

    // the health ledger saw the lifecycle: a failure and a restart on
    // replica 0 (heartbeat samples keep flowing, so poll briefly)
    wait_until("recorder replica health", Duration::from_secs(5), || {
        let reps = coord.recorder.replica_snapshot();
        reps[0].restarts >= 1 && reps[0].generation >= 1
    });

    // post-recovery traffic completes with zero failures
    let mut rxs = Vec::new();
    for i in 0..10u64 {
        rxs.push((1000 + i, coord.submit(spec(i as usize)).expect("admit")));
    }
    let out = classify(drain(rxs), coord.num_labels());
    assert_eq!(out.completed.len(), 10, "recovered pool must serve cleanly");
    assert_eq!(coord.queue_depth(), 0);
}

/// Watchdog path: a replica that stalls inside a device call (no thread
/// death) is declared dead once its heartbeat exceeds the budget; its
/// queue is reclaimed onto the survivor and the slot restarts.  The
/// stalled incarnation's late wake-up must not corrupt anything — its
/// queue is poisoned and its generation is stale.
#[test]
fn watchdog_detects_stall_and_supervisor_recovers() {
    let dir = fake_artifacts("stall", FAKE_MANIFEST);
    let coord = Coordinator::start(
        dir,
        &routes(),
        ServerConfig {
            replicas: 2,
            watchdog: Some(Duration::from_millis(100)),
            fault_plan: FaultPlan::default().with(FaultSpec::on(
                0,
                FaultKind::StallFor { batch: 0, dur: Duration::from_millis(1500) },
            )),
            ..config(2)
        },
    )
    .unwrap();

    let total = 12u64;
    let mut rxs = Vec::new();
    for i in 0..total {
        rxs.push((i, coord.submit(spec(i as usize)).expect("admit")));
    }
    let out = classify(drain(rxs), coord.num_labels());
    assert_eq!(out.completed.len() + out.failed, total as usize);
    assert!(out.failed >= 1, "the stalled batch must fail");
    assert!(
        out.completed.len() >= total as usize - 2,
        "only the stalled batch may fail (drained work must resubmit): {} completed",
        out.completed.len()
    );
    assert_eq!(coord.queue_depth(), 0);

    wait_until("stalled replica restart", Duration::from_secs(10), || {
        coord.engine().live_replicas() == 2 && coord.engine().replica_restarts(0) >= 1
    });
    let snap = coord.recorder.snapshot();
    let s = &snap["fp"];
    assert_eq!(s.requests, total);
    assert_eq!(s.requests, s.completed + s.errors + s.expired + s.failed);
}

/// Circuit breaker: a replica that crashes at the first batch of every
/// incarnation burns through its restart budget and is excluded for the
/// life of the pool; the pool keeps serving on the survivor.
#[test]
fn circuit_breaker_excludes_permanently_crashing_replica() {
    let dir = fake_artifacts("breaker", FAKE_MANIFEST);
    let coord = Coordinator::start(
        dir,
        &routes(),
        ServerConfig {
            replicas: 2,
            restart: RestartPolicy {
                backoff: Duration::from_millis(1),
                max_backoff: Duration::from_millis(5),
                budget: 3,
                window: Duration::from_secs(60),
            },
            fault_plan: FaultPlan::default()
                .with(FaultSpec::on(0, FaultKind::PanicAt { batch: 0 }).persistent()),
            ..config(1)
        },
    )
    .unwrap();

    // drive single requests until the breaker trips: whenever replica 0
    // is live (and idle it wins the lowest-index tie) the next batch
    // lands there and kills the incarnation; budget 3 deaths -> excluded
    let t0 = Instant::now();
    let mut failed = 0usize;
    let mut completed = 0usize;
    while !coord.engine().replica_excluded(0) {
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "breaker never tripped: {failed} failed / {completed} completed so far"
        );
        let rx = coord.submit(spec(completed + failed)).expect("admit");
        let resp = rx.recv_timeout(Duration::from_secs(30)).expect("reply");
        if resp.failed {
            failed += 1;
        } else {
            assert!(resp.error.is_none(), "{:?}", resp.error);
            completed += 1;
        }
        // give the supervisor a beat to cycle backoff -> restart
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(failed >= 3, "budget is 3 deaths, saw only {failed} failed replies");
    assert_eq!(coord.engine().live_replicas(), 1, "survivor must stay in service");

    // terminal: the exclusion is permanent and the pool serves on
    let mut rxs = Vec::new();
    for i in 0..8u64 {
        rxs.push((i, coord.submit(spec(i as usize)).expect("admit")));
    }
    let out = classify(drain(rxs), coord.num_labels());
    assert_eq!(out.completed.len(), 8, "survivor must carry all traffic");
    assert!(coord.engine().replica_excluded(0), "exclusion must be terminal");
    wait_until("excluded flag in health ledger", Duration::from_secs(5), || {
        coord.recorder.replica_snapshot()[0].excluded
    });
    assert_eq!(coord.queue_depth(), 0);
}

/// FailSubmit: a replica that stops accepting work (queue closed) after
/// its first batch is not a client-visible failure at all — queued work
/// drains, later batches reroute to the survivor, and the supervisor
/// recycles the slot once the thread exits.
#[test]
fn fail_submit_reroutes_without_client_failures() {
    let dir = fake_artifacts("failsubmit", FAKE_MANIFEST);
    let coord = Coordinator::start(
        dir,
        &routes(),
        ServerConfig {
            replicas: 2,
            // a wide backoff keeps the slot in its dead window while the
            // second wave submits, so the reroute path is actually taken
            restart: RestartPolicy { backoff: Duration::from_millis(500), ..Default::default() },
            fault_plan: FaultPlan::default()
                .with(FaultSpec::on(0, FaultKind::FailSubmit { after_batch: 0 })),
            ..config(2)
        },
    )
    .unwrap();

    // wave 1 lands on replica 0 (lowest-index tie) and closes its queue
    let mut rxs = Vec::new();
    for i in 0..4u64 {
        rxs.push((i, coord.submit(spec(i as usize)).expect("admit")));
    }
    let out = classify(drain(rxs), coord.num_labels());
    assert_eq!((out.completed.len(), out.failed), (4, 0), "drained work must complete");

    // wave 2 must reroute: replica 0 rejects pushes (or is already dead)
    let mut rxs = Vec::new();
    for i in 0..8u64 {
        rxs.push((100 + i, coord.submit(spec(i as usize)).expect("admit")));
    }
    let out = classify(drain(rxs), coord.num_labels());
    assert_eq!((out.completed.len(), out.failed), (8, 0), "reroute must be invisible");
    assert!(
        coord.recorder.replica_snapshot()[1].batches >= 1,
        "survivor replica never executed a batch — nothing rerouted"
    );

    // the graceful exit still cycles the slot through supervised restart
    wait_until("closed slot restart", Duration::from_secs(10), || {
        coord.engine().replica_restarts(0) >= 1 && coord.engine().live_replicas() == 2
    });
    let snap = coord.recorder.snapshot();
    assert_eq!(snap["fp"].failed, 0, "FailSubmit must not fail a single request");
    assert_eq!(coord.queue_depth(), 0);
}

/// The full four-class ledger under chaos: deadlines + a tight admission
/// cap + a mid-run replica panic produce shed, expired, failed, and
/// completed traffic at once — and the ledger still reconciles exactly,
/// client side and recorder side.
#[test]
fn chaos_overload_ledger_reconciles_with_all_outcome_classes() {
    let dir = fake_artifacts("ledger", FAKE_MANIFEST);
    let coord = Coordinator::start(
        dir,
        &routes(),
        ServerConfig {
            replicas: 2,
            queue_cap: 8,
            default_deadline: Some(Duration::from_millis(30)),
            fault_plan: FaultPlan::default()
                .with(FaultSpec::on(0, FaultKind::PanicAt { batch: 2 })),
            ..config(8)
        },
    )
    .unwrap();

    let total = 80usize;
    let mut shed = 0usize;
    let mut rxs = Vec::new();
    for i in 0..total {
        match coord.submit(spec(i)) {
            Ok(rx) => rxs.push((i as u64, rx)),
            Err(e) if e.is_busy() => shed += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
        if i % 8 == 7 {
            std::thread::sleep(Duration::from_millis(3));
        }
    }
    assert!(coord.queue_depth() <= 8, "backlog bound exceeded");

    let out = classify(drain(rxs), coord.num_labels());
    let completed = out.completed.len();

    // the four-class ledger reconciles exactly, client side ...
    assert_eq!(
        total,
        completed + shed + out.expired + out.failed,
        "admitted != completed + shed + expired + failed"
    );
    assert!(shed > 0, "never hit the admission cap — not an overload test");
    assert!(out.failed > 0, "the panicked batch never failed anyone");
    assert!(completed > 0, "nothing survived");

    // ... and recorder side
    let snap = coord.recorder.snapshot();
    let s = &snap["fp"];
    assert_eq!(s.shed as usize, shed);
    assert_eq!(s.expired as usize, out.expired);
    assert_eq!(s.failed as usize, out.failed);
    assert_eq!(s.completed as usize, completed);
    assert_eq!(s.requests as usize, total - shed);
    assert_eq!(s.errors, 0);
    assert_eq!(s.requests, s.completed + s.errors + s.expired + s.failed);

    // dispatch FIFO among survivors across shed/expiry/failure churn
    let mut survivors = out.completed;
    survivors.sort_by_key(|r| r.id);
    let seqs: Vec<u64> = survivors.iter().map(|r| r.timing.batch_seq).collect();
    let mut sorted = seqs.clone();
    sorted.sort_unstable();
    assert_eq!(seqs, sorted, "survivors out of dispatch order");

    // after full drain the backlog returns to zero and capacity recovers
    assert_eq!(coord.queue_depth(), 0, "backlog slots leaked");
    wait_until("capacity recovery", Duration::from_secs(10), || {
        coord.engine().live_replicas() == 2
    });
}

/// Hot manifest reload mid-burst (DESIGN.md §5.13): requests admitted
/// before the reload drain on version 0 while new admissions ride
/// version 1 — zero client-visible failures, and the ledger identity
/// `requests == completed + errors + expired + failed` holds on both
/// versions' slots independently.
#[test]
fn manifest_reload_mid_burst_drains_cleanly_on_both_versions() {
    let dir = fake_artifacts("reload", FAKE_MANIFEST);
    let coord =
        Coordinator::start(dir.clone(), &routes(), ServerConfig { replicas: 2, ..config(3) })
            .unwrap();
    assert_eq!(coord.current_version(), 0);

    let wave = 24u64;
    let mut rxs = Vec::new();
    for i in 0..wave {
        rxs.push((i, coord.submit(spec(i as usize)).expect("admit")));
    }
    // rewrite the manifest in place (identical grid: hot reload is a
    // weights refresh, never a topology change) and swap mid-drain
    std::fs::write(dir.join("manifest.json"), FAKE_MANIFEST).expect("rewrite manifest");
    let v = coord.reload().expect("grid-compatible reload must be accepted");
    assert_eq!((v, coord.current_version()), (1, 1));
    for i in wave..2 * wave {
        rxs.push((i, coord.submit(spec(i as usize)).expect("admit")));
    }
    let out = classify(drain(rxs), coord.num_labels());
    assert_eq!(out.completed.len() as u64, 2 * wave, "reload must be client-invisible");
    assert_eq!((out.failed, out.expired), (0, 0));
    assert_eq!(coord.queue_depth(), 0, "backlog slots leaked across the reload");

    // recorder side: one slot block per version, each reconciling alone
    let snap = coord.recorder.snapshot();
    let v0 = &snap["fp"];
    let v1 = &snap["fp@v1"];
    assert_eq!(v0.requests, v0.completed + v0.errors + v0.expired + v0.failed);
    assert_eq!(v1.requests, v1.completed + v1.errors + v1.expired + v1.failed);
    assert_eq!((v0.errors, v0.failed, v1.errors, v1.failed), (0, 0, 0, 0));
    assert_eq!(v0.requests, wave, "pre-reload admissions drain on v0");
    assert_eq!(v1.requests, wave, "post-reload admissions ride v1");

    // versions are monotone: the next swap mints v2, and traffic still
    // completes cleanly on it
    assert_eq!(coord.reload().expect("second reload"), 2);
    let mut rxs = Vec::new();
    for i in 0..8u64 {
        rxs.push((1000 + i, coord.submit(spec(i as usize)).expect("admit")));
    }
    let out = classify(drain(rxs), coord.num_labels());
    assert_eq!(out.completed.len(), 8, "post-reload pool must serve cleanly");
}

/// A corrupt artifact cell is deterministic: when a restarted
/// incarnation's preload fails with a typed `PreloadError`, the
/// supervisor must exclude the slot immediately — no restart-budget
/// crash loop against the same broken cell — and the pool serves on
/// the survivor (DESIGN.md §5.13).
#[test]
fn preload_failure_on_restart_excludes_immediately() {
    let dir = fake_artifacts("preload", FAKE_MANIFEST);
    let coord = Coordinator::start(
        dir,
        &routes(),
        ServerConfig {
            replicas: 2,
            restart: RestartPolicy {
                backoff: Duration::from_millis(1),
                max_backoff: Duration::from_millis(5),
                budget: 5,
                window: Duration::from_secs(60),
            },
            fault_plan: FaultPlan::default()
                .with(FaultSpec::on(0, FaultKind::PanicAt { batch: 1 }))
                .with(FaultSpec::on(0, FaultKind::FailPreload).from_gen(1).persistent()),
            ..config(2)
        },
    )
    .unwrap();

    // the original incarnation preloads fine (FailPreload gates on
    // generation >= 1) and dies on its second batch; the respawned
    // incarnation then fails preload with the typed error
    let total = 24u64;
    let mut rxs = Vec::new();
    for i in 0..total {
        rxs.push((i, coord.submit(spec(i as usize)).expect("admit")));
    }
    let out = classify(drain(rxs), coord.num_labels());
    assert_eq!(out.completed.len() + out.failed, total as usize);
    assert!(out.failed >= 1, "the panicked batch must fail its requests");

    // exclusion must be immediate — one typed preload failure, not
    // `budget` crash-looped incarnations — and a spawn that never
    // reached ready must not ledger as a completed restart
    wait_until("typed-preload exclusion", Duration::from_secs(10), || {
        coord.engine().replica_excluded(0)
    });
    assert_eq!(
        coord.engine().replica_restarts(0),
        0,
        "a failed preload must not count as a completed restart"
    );
    assert_eq!(coord.engine().live_replicas(), 1, "survivor must stay in service");
    wait_until("excluded flag in health ledger", Duration::from_secs(5), || {
        coord.recorder.replica_snapshot()[0].excluded
    });

    // the survivor carries all traffic; the ledger still reconciles
    let mut rxs = Vec::new();
    for i in 0..8u64 {
        rxs.push((100 + i, coord.submit(spec(i as usize)).expect("admit")));
    }
    let out = classify(drain(rxs), coord.num_labels());
    assert_eq!(out.completed.len(), 8, "survivor must carry all traffic");
    let snap = coord.recorder.snapshot();
    let s = &snap["fp"];
    assert_eq!(s.requests, s.completed + s.errors + s.expired + s.failed);
    assert_eq!(coord.queue_depth(), 0);
}
