//! heromck — a dependency-free schedule-exploring concurrency checker
//! (DESIGN.md §5.12).
//!
//! herolint (§5.11) checks the serving spine's concurrency disciplines
//! *syntactically*; heromck checks them *semantically*, by executing
//! test bodies under a deterministic cooperative scheduler that
//! enumerates interleavings.  The real tool for this, loom, is
//! unavailable offline, so — in the same spirit as `prop::forall` and
//! `lint/` — the model checker is built in-repo:
//!
//! * [`sync`] — instrumented doubles of the `std::sync` surface the
//!   spine uses (`Mutex`, `Condvar`, `RwLock`, atomics with modeled
//!   `Ordering` semantics, `mpsc` channels) that fall back to plain
//!   `std` outside model runs;
//! * [`thread`] — modeled `spawn`/`join`/`sleep`;
//! * [`explore`] — a bounded-preemption exhaustive DFS plus a seeded
//!   PCT-style randomized mode, with **replayable failure schedules**:
//!   a failing run prints its schedule token, and `MCK_REPLAY=<token>`
//!   re-executes that exact interleaving.
//!
//! The crate-level `crate::sync` facade re-exports `std::sync` in
//! normal builds and these types under `--features heromck`, so the
//! spine's own code can be driven through the model unchanged.

pub(crate) mod sched;

pub mod explore;
pub mod sync;
pub mod thread;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64 as StdAtomicU64, Ordering as StdOrdering};
use std::sync::{Arc, Mutex as StdMutex, Once};

pub use explore::{check, check_result, replay, Config, Failure, Outcome, Stats};

use crate::json;
use sched::{Controller, TracePoint};

/// The calling thread's link to the active model run, if any.
#[derive(Clone)]
pub(crate) struct RunHandle {
    pub(crate) ctl: Arc<Controller>,
    pub(crate) tid: usize,
}

thread_local! {
    static CURRENT: RefCell<Option<RunHandle>> = RefCell::new(None);
}

pub(crate) fn current() -> Option<RunHandle> {
    CURRENT.with(|c| c.borrow().clone())
}

pub(crate) fn set_current(h: Option<RunHandle>) {
    CURRENT.with(|c| *c.borrow_mut() = h);
}

/// Epochs start at 1; registration cells default to epoch 0, so a fresh
/// primitive never matches a run it was not registered with.
static EPOCH: StdAtomicU64 = StdAtomicU64::new(1);

pub(crate) fn next_epoch() -> u64 {
    EPOCH.fetch_add(1, StdOrdering::SeqCst)
}

// ----------------------------------------------------------- token codec

/// Encode a decision trace as a replay token: `mck1` followed by the
/// chosen index of every *recorded* decision (single-option points are
/// not recorded, in recording and replay alike).
pub(crate) fn encode_token(trace: &[TracePoint]) -> String {
    let mut s = String::from("mck1");
    for p in trace {
        s.push('.');
        s.push_str(&p.chosen.to_string());
    }
    s
}

/// Decode a replay token into a forced decision prefix.  `None` on
/// malformed input (wrong version tag or non-numeric segment).
pub fn decode_token(token: &str) -> Option<Vec<usize>> {
    let rest = token.strip_prefix("mck1")?;
    if rest.is_empty() {
        return Some(Vec::new());
    }
    rest.strip_prefix('.')?
        .split('.')
        .map(|p| p.parse::<usize>().ok())
        .collect()
}

// ------------------------------------------------------------ panic hook

static HOOK: Once = Once::new();

/// Model threads fail schedules by panicking; without this the default
/// hook would spray backtraces for every unwound thread of every failing
/// schedule (and for the `MckAbort` teardown of innocent ones).  Threads
/// are named `mck-*`, so the filter is precise.
pub(crate) fn install_quiet_hook() {
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let quiet = std::thread::current()
                .name()
                .map(|n| n.starts_with("mck-"))
                .unwrap_or(false);
            if !quiet {
                prev(info);
            }
        }));
    });
}

// -------------------------------------------------------- bench artifact

#[derive(Clone)]
struct TestStat {
    schedules: usize,
    max_depth: usize,
    failed: bool,
}

static REGISTRY: StdMutex<Option<BTreeMap<String, TestStat>>> = StdMutex::new(None);

/// Record one exploration outcome; when `MCK_BENCH_JSON` names a file,
/// rewrite the trend artifact with everything recorded so far (each
/// test completion updates it, so a partial run still leaves a valid
/// artifact).
pub(crate) fn record_outcome(name: &str, out: &Outcome) {
    let snapshot: Vec<(String, TestStat)> = {
        let mut g = match REGISTRY.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let map = g.get_or_insert_with(BTreeMap::new);
        map.insert(
            name.to_string(),
            TestStat {
                schedules: out.stats.schedules,
                max_depth: out.stats.max_depth,
                failed: out.failure.is_some(),
            },
        );
        map.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
    };
    if let Ok(path) = std::env::var("MCK_BENCH_JSON") {
        if !path.is_empty() {
            let _ = write_bench(&path, &snapshot);
        }
    }
}

/// `BENCH_lint_mck.json`: herolint finding/suppression counts plus
/// heromck exploration volume, for CI trend tracking.
fn write_bench(path: &str, tests: &[(String, TestStat)]) -> std::io::Result<()> {
    let schedules: usize = tests.iter().map(|(_, t)| t.schedules).sum();
    let max_depth: usize = tests.iter().map(|(_, t)| t.max_depth).max().unwrap_or(0);
    let failures: usize = tests.iter().filter(|(_, t)| t.failed).count();
    let lint = match crate::lint::lint_tree(&Path::new(env!("CARGO_MANIFEST_DIR")).join("src")) {
        Ok(r) => json::obj(vec![
            ("findings", json::num(r.analysis.findings.len() as f64)),
            ("suppressed_panic", json::num(r.analysis.suppressed_panic as f64)),
            ("suppressed_relaxed", json::num(r.analysis.suppressed_relaxed as f64)),
            ("suppressed_block", json::num(r.analysis.suppressed_block as f64)),
            ("lock_edges", json::num(r.analysis.edges.len() as f64)),
        ]),
        Err(e) => json::obj(vec![("error", json::s(&e.to_string()))]),
    };
    let per_test: Vec<json::Value> = tests
        .iter()
        .map(|(name, t)| {
            json::obj(vec![
                ("name", json::s(name)),
                ("schedules", json::num(t.schedules as f64)),
                ("max_depth", json::num(t.max_depth as f64)),
                ("failed", json::Value::Bool(t.failed)),
            ])
        })
        .collect();
    let v = json::obj(vec![
        ("bench", json::s("lint_mck")),
        ("lint", lint),
        (
            "mck",
            json::obj(vec![
                ("tests", json::num(tests.len() as f64)),
                ("schedules_explored", json::num(schedules as f64)),
                ("max_schedule_depth", json::num(max_depth as f64)),
                ("failing_tests", json::num(failures as f64)),
            ]),
        ),
        ("per_test", json::Value::Array(per_test)),
    ]);
    std::fs::write(path, json::to_string_pretty(&v))
}

// ------------------------------------------------------------ self-tests

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::sync::atomic::{AtomicU64, Ordering};
    use super::sync::{mpsc, Condvar, Mutex};
    use super::{check, check_result, decode_token, replay, thread, Config};

    fn small() -> Config {
        Config { max_schedules: 500, pct_iters: 8, ..Config::default() }
    }

    #[test]
    fn token_codec_round_trips() {
        assert_eq!(decode_token("mck1"), Some(vec![]));
        assert_eq!(decode_token("mck1.0.2.1"), Some(vec![0, 2, 1]));
        assert_eq!(decode_token("mck2.0"), None);
        assert_eq!(decode_token("mck1.x"), None);
        assert_eq!(decode_token(""), None);
    }

    #[test]
    fn primitives_fall_back_to_std_outside_model_runs() {
        let m = Mutex::new(1u32);
        *m.lock().unwrap() += 1;
        assert_eq!(*m.lock().unwrap(), 2);
        let (tx, rx) = mpsc::channel();
        tx.send(7u32).unwrap();
        assert_eq!(rx.recv().unwrap(), 7);
        let a = AtomicU64::new(1);
        a.fetch_add(2, Ordering::SeqCst);
        assert_eq!(a.load(Ordering::SeqCst), 3);
        let h = thread::spawn(|| 5u32);
        assert_eq!(h.join().unwrap(), 5);
    }

    #[test]
    fn mutex_counter_is_race_free() {
        let out = check("mutex-counter", small(), || {
            let n = Arc::new(Mutex::new(0u32));
            let mut hs = Vec::new();
            for _ in 0..2 {
                let n = n.clone();
                hs.push(thread::spawn(move || {
                    *n.lock().unwrap() += 1;
                }));
            }
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(*n.lock().unwrap(), 2);
        });
        assert!(out.stats.schedules > 1, "exploration should cover several interleavings");
    }

    #[test]
    fn fetch_add_counter_is_clean() {
        check("fetch-add-counter", small(), || {
            let n = Arc::new(AtomicU64::new(0));
            let mut hs = Vec::new();
            for _ in 0..2 {
                let n = n.clone();
                hs.push(thread::spawn(move || {
                    n.fetch_add(1, Ordering::SeqCst);
                }));
            }
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(n.load(Ordering::SeqCst), 2);
        });
    }

    #[test]
    fn racy_increment_is_caught_and_replays() {
        // load-then-store is a lost update waiting to happen; the model
        // must find a schedule where both threads read the same value
        let body = || {
            let n = Arc::new(AtomicU64::new(0));
            let mut hs = Vec::new();
            for _ in 0..2 {
                let n = n.clone();
                hs.push(thread::spawn(move || {
                    let v = n.load(Ordering::Relaxed);
                    n.store(v + 1, Ordering::Relaxed);
                }));
            }
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(n.load(Ordering::SeqCst), 2, "lost update");
        };
        let out = check_result("racy-increment", small(), body);
        let f = out.failure.expect("exploration should find the lost update");
        assert_eq!(f.kind, "panic");
        assert!(f.token.starts_with("mck1"), "token {:?}", f.token);
        // the token replays the exact failing interleaving
        let re = replay(&small(), body, &f.token);
        let rf = re.failure.expect("replay must reproduce the failure");
        assert_eq!(rf.kind, f.kind);
        assert_eq!(rf.token, f.token);
    }

    #[test]
    fn lock_order_inversion_deadlocks_with_held_report() {
        let out = check_result("ab-ba-deadlock", small(), || {
            let a = Arc::new(Mutex::new_named("lock A", ()));
            let b = Arc::new(Mutex::new_named("lock B", ()));
            let (a2, b2) = (a.clone(), b.clone());
            let t = thread::spawn(move || {
                let _x = b2.lock().unwrap();
                let _y = a2.lock().unwrap();
            });
            {
                let _x = a.lock().unwrap();
                let _y = b.lock().unwrap();
            }
            let _ = t.join();
        });
        let f = out.failure.expect("exploration should find the AB/BA deadlock");
        assert_eq!(f.kind, "deadlock");
        assert!(
            f.held.iter().any(|h| h.contains("lock A"))
                && f.held.iter().any(|h| h.contains("lock B")),
            "held-lock report should name both locks: {:?}",
            f.held
        );
        // both acquisition orders were observed on the way
        assert!(out.edges.contains(&("lock A".to_string(), "lock B".to_string())));
        assert!(out.edges.contains(&("lock B".to_string(), "lock A".to_string())));
    }

    #[test]
    fn missed_notify_is_reported_as_deadlock() {
        let out = check_result("missed-notify", small(), || {
            let pair = Arc::new((Mutex::new(()), Condvar::new()));
            let p2 = pair.clone();
            let t = thread::spawn(move || {
                let g = p2.0.lock().unwrap();
                // BUG: unconditional wait — a notify that fires before
                // this thread parks is lost forever
                let _g = p2.1.wait(g).unwrap();
            });
            pair.1.notify_one();
            let _ = t.join();
        });
        let f = out.failure.expect("the lost notification should deadlock some schedule");
        assert_eq!(f.kind, "deadlock");
        assert!(f.message.contains("blocked"), "message: {}", f.message);
    }

    #[test]
    fn condvar_with_predicate_loop_is_clean() {
        check("condvar-predicate", small(), || {
            let pair = Arc::new((Mutex::new(false), Condvar::new()));
            let p2 = pair.clone();
            let t = thread::spawn(move || {
                let mut g = p2.0.lock().unwrap();
                while !*g {
                    g = p2.1.wait(g).unwrap();
                }
            });
            *pair.0.lock().unwrap() = true;
            pair.1.notify_one();
            t.join().unwrap();
        });
    }

    #[test]
    fn bounded_channel_blocks_and_drains() {
        check("bounded-channel", small(), || {
            let (tx, rx) = mpsc::sync_channel::<u32>(1);
            let t = thread::spawn(move || {
                tx.send(1).unwrap();
                tx.send(2).unwrap();
            });
            assert_eq!(rx.recv().unwrap(), 1);
            assert_eq!(rx.recv().unwrap(), 2);
            t.join().unwrap();
        });
    }

    #[test]
    fn release_acquire_publishes_data() {
        // the classic message-passing litmus: with Release/Acquire the
        // reader that sees the flag must see the payload
        check("release-acquire-publish", small(), || {
            let flag = Arc::new(AtomicU64::new(0));
            let data = Arc::new(AtomicU64::new(0));
            let (f2, d2) = (flag.clone(), data.clone());
            let t = thread::spawn(move || {
                d2.store(42, Ordering::Relaxed);
                f2.store(1, Ordering::Release);
            });
            if flag.load(Ordering::Acquire) == 1 {
                assert_eq!(data.load(Ordering::Relaxed), 42, "acquire must see the payload");
            }
            t.join().unwrap();
        });
    }

    #[test]
    fn relaxed_flag_does_not_publish_data() {
        // the same litmus with a Relaxed flag load must fail: the model
        // lets the data load observe the stale store
        let out = check_result("relaxed-no-publish", small(), || {
            let flag = Arc::new(AtomicU64::new(0));
            let data = Arc::new(AtomicU64::new(0));
            let (f2, d2) = (flag.clone(), data.clone());
            let t = thread::spawn(move || {
                d2.store(42, Ordering::Relaxed);
                f2.store(1, Ordering::Relaxed);
            });
            if flag.load(Ordering::Relaxed) == 1 {
                assert_eq!(data.load(Ordering::Relaxed), 42, "no ordering, no guarantee");
            }
            t.join().unwrap();
        });
        assert!(out.failure.is_some(), "relaxed publish must be caught");
    }
}
